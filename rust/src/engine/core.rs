//! The event-driven single-core engine: two-phase HBM spike routing
//! (paper §4) with access and cycle accounting.
//!
//! Step structure (matches the hardware, Fig 8 and the dense engine
//! bit-for-bit):
//!
//! 1. **membrane sweep** — phases 1-3 via the pluggable
//!    [`UpdateBackend`] (native Rust or the AOT Pallas artifact through
//!    PJRT). URAM read+write per neuron. The backend writes a packed
//!    `u64` spike bitmask (the BRAM spike registers); fired ids are
//!    decoded with [`extract_fired`], which skips zero words whole and
//!    walks set bits via `trailing_zeros` — at the paper's sparse
//!    activity levels this replaces an O(N) per-neuron scan with ~N/64
//!    word loads (§Perf: the dominant per-step cost at n >= 100k).
//! 2. **phase 1 routing** — for every fired axon (BRAM spike registers)
//!    and fired neuron, fetch its HBM pointer; pointer-row reads are
//!    burst-deduplicated (16 pointers/row).
//! 3. **phase 2 routing** — stream each pointer's synapse-region rows,
//!    gathering events into interleaved `(target, weight)` buffers.
//! 4. **accumulate** — the backend consumes the gathered buffers
//!    directly (fused with the gather's write order: one stream through
//!    the event cache lines instead of the seed's parallel
//!    targets/weights arrays and second full pass).
//!
//! # Route-phase split and the chunk-merge ordering contract
//!
//! Like the membrane sweep (`sweep_view`/`finish_update`), the route
//! phase is split three ways so `cluster::CorePool` can run its hot
//! middle chunk-parallel:
//!
//! * `route_prepare` — serial phase-1: BRAM accounting and
//!   pointer fetches (the row-burst dedup walks the fired list in order,
//!   so this stays on one thread), plus chunk geometry: the pointer
//!   queue is cut into fixed-size chunks, one gather buffer per chunk.
//! * the **gather** — each chunk `c` streams pointers
//!   `[c*K, (c+1)*K)` of the queue through [`UpdateBackend::gather`]
//!   into its own buffer `gather_bufs[c]` (the crate-internal
//!   `gather_chunk`, driven directly by the serial path and through a
//!   raw-pointer `RouteView` by the pool workers). Chunks only read the
//!   HBM image and write their own buffer, so any number of workers may
//!   run them in any order.
//! * `route_finish` — the merge/accumulate epilogue:
//!   row/event accounting reconstructed from the queue and buffer
//!   lengths (bit-identical totals to the serial counting), then the
//!   buffers are consumed **in ascending chunk index order** — which
//!   concatenates to exactly the serial gather stream, so the
//!   accumulate (wrapping adds today, any order-sensitive arithmetic
//!   tomorrow) and every golden transcript stay bit-identical to
//!   [`CoreEngine::phase_route`] run serially.
//!
//! `phase_route` itself is `route_prepare` (one whole-queue chunk) + a
//! serial gather + `route_finish`, so the serial and chunk-parallel
//! paths share one implementation; `rust/tests/chunked_route.rs` pins
//! the equivalence across chunk sizes and worker counts.
//!
//! The engine never allocates in the hot loop after warm-up: all queues
//! and gather buffers are reused.

use crate::energy::{CostReport, EnergyModel};
use crate::engine::backend::{extract_fired, mask_words, CoreParams, UpdateBackend};
use crate::hbm::{AccessCounters, HbmImage, HbmSim, Pointer, SlotStrategy, SynEntry, SYN_VALID};
use crate::plasticity::{
    apply_delta, decay_trace, stdp_delta, trace_chunk, PlasticState, PlasticityConfig, TRACE_CEIL,
    TRACE_ONE,
};
use crate::snn::NetView;
use crate::util::prng::mix_seed;

/// Raw pointers into one engine's sweep state, handed to `CorePool`
/// workers for the chunk-parallel membrane sweep. Valid only while the
/// engine stays boxed (stable address) and the pool driver is blocked in
/// its Update phase; chunks address disjoint word-aligned ranges, so
/// workers never alias.
#[derive(Clone, Copy, Debug)]
pub(crate) struct SweepView {
    pub v: *mut i32,
    pub spikes: *mut u64,
    pub params: *const CoreParams,
    pub n: usize,
    pub step_seed: u32,
    /// STDP trace columns (null when plasticity is off). Chunks update
    /// their own word-aligned trace range right after the sweep — the
    /// trace kernel is per-lane independent, so this inherits the
    /// sweep's chunking invariance.
    pub trace_pre: *mut i32,
    pub trace_post: *mut i32,
    pub tau_pre: u32,
    pub tau_post: u32,
}

/// Raw pointers into one engine's prepared route state, handed to
/// `CorePool` workers for the chunk-parallel gather. Valid only between
/// [`CoreEngine::route_prepare`] and [`CoreEngine::route_finish`] while
/// the engine stays boxed and the pool driver is blocked in its
/// RouteGather phase. Chunk `c` reads pointers
/// `[c*chunk_ptrs, (c+1)*chunk_ptrs).min(n_ptrs)` of the queue and owns
/// buffer slot `c` exclusively; the image and backend are only read.
#[derive(Clone, Copy, Debug)]
pub(crate) struct RouteView<B> {
    pub image: *const HbmImage,
    pub backend: *const B,
    pub ptrs: *const Pointer,
    pub n_ptrs: usize,
    /// base of the engine's `gather_bufs`; slot `c` belongs to chunk `c`
    pub bufs: *mut Vec<(u32, i32)>,
    pub n_chunks: usize,
    pub chunk_ptrs: usize,
}

/// Result of one engine step (borrowed views into reusable buffers).
#[derive(Debug)]
pub struct StepOutput<'a> {
    /// Fired neuron ids, ascending.
    pub fired: &'a [u32],
    /// Fired output neurons (subset of `fired`).
    pub output_spikes: &'a [u32],
}

/// Event-driven execution of one core.
pub struct CoreEngine<B: UpdateBackend> {
    pub hbm: HbmSim,
    params: CoreParams,
    pub v: Vec<i32>,
    backend: B,
    pub base_seed: u32,
    pub step_num: u32,
    /// Cycle counter since the last `reset_cost()`.
    pub cycles: u64,
    is_output: Vec<bool>,
    // reusable buffers
    spike_words: Vec<u64>,
    fired_buf: Vec<u32>,
    fired_sorted: Vec<u32>,
    out_buf: Vec<u32>,
    ptr_queue: Vec<Pointer>,
    /// per-chunk phase-2 event buffers; `route_chunks` of them are live
    /// between `route_prepare` and `route_finish` (see module docs)
    gather_bufs: Vec<Vec<(u32, i32)>>,
    /// chunk geometry of the current route phase (set by `route_prepare`)
    route_chunks: usize,
    route_chunk_ptrs: usize,
    /// phase-1 pointer-row delta of the current route phase (for the
    /// cycle accounting in `route_finish`)
    route_ptr_rows: u64,
    /// opt-in STDP learning state (traces + reverse in-edge index);
    /// see `crate::plasticity` for the ordering contract
    plastic: Option<Box<PlasticState>>,
}

impl<B: UpdateBackend> CoreEngine<B> {
    /// Crate-private: external callers construct engines through
    /// [`crate::sim::SimConfig`] (the facade is the public contract).
    /// Generic over the borrowed-CSR view, so an mmap-backed `.hsn` v2
    /// compiles straight from the mapping.
    pub(crate) fn new<'a>(
        net: impl Into<NetView<'a>>,
        strategy: SlotStrategy,
        backend: B,
    ) -> anyhow::Result<Self> {
        let net: NetView<'_> = net.into();
        let image = HbmImage::compile(net, strategy)?;
        Ok(Self::from_image(net, image, backend))
    }

    pub(crate) fn from_image<'a>(net: impl Into<NetView<'a>>, image: HbmImage, backend: B) -> Self {
        let net: NetView<'_> = net.into();
        let n = net.n_neurons();
        let mut is_output = vec![false; n];
        for &o in net.outputs {
            is_output[o as usize] = true;
        }
        Self {
            hbm: HbmSim::new(image),
            params: CoreParams::from_network(net),
            v: vec![0; n],
            backend,
            base_seed: net.base_seed,
            step_num: 0,
            cycles: 0,
            is_output,
            spike_words: vec![0; mask_words(n)],
            fired_buf: Vec::with_capacity(n),
            fired_sorted: Vec::with_capacity(n),
            out_buf: Vec::new(),
            ptr_queue: Vec::new(),
            gather_bufs: Vec::new(),
            route_chunks: 0,
            route_chunk_ptrs: usize::MAX,
            route_ptr_rows: 0,
            plastic: None,
        }
    }

    /// Opt in to pair-based STDP (see `crate::plasticity` for the rule
    /// and the trace/update ordering contract). Builds the traces and
    /// the reverse in-edge index over the compiled image; call before
    /// the first step (traces start at zero).
    pub(crate) fn enable_plasticity(&mut self, cfg: PlasticityConfig) -> anyhow::Result<()> {
        cfg.validate().map_err(|e| anyhow::anyhow!("invalid learning config: {e}"))?;
        self.plastic = Some(Box::new(PlasticState::from_image(&self.hbm.image, cfg)));
        Ok(())
    }

    /// True when STDP learning is enabled on this engine.
    pub fn plasticity_enabled(&self) -> bool {
        self.plastic.is_some()
    }

    /// STDP weight deltas applied since construction (diagnostics).
    pub fn stdp_events(&self) -> u64 {
        self.plastic.as_ref().map_or(0, |p| p.events)
    }

    pub fn n_neurons(&self) -> usize {
        self.v.len()
    }

    pub fn reset(&mut self) {
        self.v.iter_mut().for_each(|x| *x = 0);
        self.step_num = 0;
        // clear last-step spike views too: after reset, fired() /
        // output_spikes() report the (empty) initial state on every
        // backend (facade contract)
        self.fired_buf.clear();
        self.out_buf.clear();
        // traces restart with the membranes; learned weights stay — a
        // reset returns the session to quiescent state, it does not
        // undo learning (compile a fresh engine for pristine weights)
        if let Some(p) = self.plastic.as_deref_mut() {
            p.reset();
        }
        self.reset_cost();
    }

    /// Clear the access/cycle counters (per-inference accounting).
    pub fn reset_cost(&mut self) {
        self.hbm.reset_counters();
        self.cycles = 0;
    }

    pub fn counters(&self) -> &AccessCounters {
        &self.hbm.counters
    }

    pub fn cost(&self, model: &EnergyModel) -> CostReport {
        model.cost(&self.hbm.counters, self.cycles)
    }

    /// One timestep. `axon_in` = fired axon ids, ascending (the BRAM axon
    /// spike register is scanned in order). Returns fired neurons and the
    /// output subset.
    ///
    /// Equivalent to `phase_update()` + `phase_route(axon_in)`; the
    /// multi-core cluster drives the two phases separately with a routing
    /// barrier in between.
    pub fn step(&mut self, axon_in: &[u32]) -> anyhow::Result<StepOutput<'_>> {
        self.phase_update()?;
        self.phase_route(axon_in)?;
        Ok(StepOutput { fired: &self.fired_buf, output_spikes: &self.out_buf })
    }

    /// Membrane sweep (phases 1-3). Fired neuron ids are available via
    /// [`Self::fired`] afterwards.
    ///
    /// The noise seed advances **here** (not in `phase_route`): each sweep
    /// consumes `mix_seed(base_seed, step_num)` and bumps `step_num`, so
    /// repeated standalone sweeps draw fresh noise while `step()` — sweep
    /// then route — sees the exact same seed schedule as before.
    pub fn phase_update(&mut self) -> anyhow::Result<()> {
        let ss = self.sweep_seed();
        self.backend.update(&mut self.v, &self.params, ss, &mut self.spike_words)?;
        // STDP step 2: decay-then-bump the neuron traces off the fresh
        // spike words (one full-range chunk here; the pool runs the
        // same kernel per sweep chunk — bit-identical either way)
        if let Some(p) = self.plastic.as_deref_mut() {
            trace_chunk(
                &self.spike_words,
                &mut p.trace_pre,
                &mut p.trace_post,
                p.cfg.tau_pre,
                p.cfg.tau_post,
            );
        }
        self.finish_update();
        Ok(())
    }

    /// Seed the next membrane sweep will consume.
    pub(crate) fn sweep_seed(&self) -> u32 {
        mix_seed(self.base_seed, self.step_num)
    }

    /// True when the backend's `update` is the pure chunkable reference
    /// kernel (see `UpdateBackend::chunkable`).
    pub(crate) fn backend_chunkable(&self) -> bool {
        self.backend.chunkable()
    }

    /// Raw sweep state for the pool's chunk-parallel Update phase. The
    /// caller must run the full sweep over these pointers and then call
    /// [`Self::finish_update`] — together the two are equivalent to
    /// [`Self::phase_update`].
    pub(crate) fn sweep_view(&mut self) -> SweepView {
        let seed = self.sweep_seed();
        let (trace_pre, trace_post, tau_pre, tau_post) = match self.plastic.as_deref_mut() {
            Some(p) => {
                (p.trace_pre.as_mut_ptr(), p.trace_post.as_mut_ptr(), p.cfg.tau_pre, p.cfg.tau_post)
            }
            None => (std::ptr::null_mut(), std::ptr::null_mut(), 0, 0),
        };
        SweepView {
            v: self.v.as_mut_ptr(),
            spikes: self.spike_words.as_mut_ptr(),
            params: &self.params,
            n: self.v.len(),
            step_seed: seed,
            trace_pre,
            trace_post,
            tau_pre,
            tau_post,
        }
    }

    /// Sweep epilogue: access/cycle accounting, fired-id extraction, and
    /// the noise-seed advance. Kept in one place so the engine's own
    /// `phase_update` and the pool's chunked sweep stay bit-identical.
    pub(crate) fn finish_update(&mut self) {
        let n = self.n_neurons();
        self.hbm.counters.uram_accesses += 2 * n as u64; // read+write per neuron
        self.cycles += self.hbm.update_cycles();
        extract_fired(&self.spike_words, &mut self.fired_buf);
        self.step_num = self.step_num.wrapping_add(1);
    }

    /// Fired neurons from the last `phase_update`.
    pub fn fired(&self) -> &[u32] {
        &self.fired_buf
    }

    /// Routing + accumulate (phases 1, 2, 4). `axon_in` includes both
    /// host inputs and router deliveries, ascending.
    ///
    /// Implemented as `route_prepare` (one whole-queue chunk) + a serial
    /// gather + `route_finish`, the exact pipeline `CorePool` drives
    /// chunk-parallel — one code path, so serial and pooled execution
    /// cannot diverge (see the module docs' ordering contract).
    pub fn phase_route(&mut self, axon_in: &[u32]) -> anyhow::Result<()> {
        self.route_prepare(axon_in, usize::MAX);
        // serial gather over the (single) chunk via the one shared
        // chunk implementation; field-split borrows — image and backend
        // are read, each buffer written once
        let image = &self.hbm.image;
        let backend = &self.backend;
        let k = self.route_chunk_ptrs;
        for (c, buf) in self.gather_bufs[..self.route_chunks].iter_mut().enumerate() {
            gather_chunk(image, backend, &self.ptr_queue, c, k, buf);
        }
        self.route_finish()
    }

    /// Route-phase prologue: BRAM accounting, serial phase-1 pointer
    /// fetches (row-burst dedup is order-dependent), and chunk geometry
    /// — the pointer queue is cut into `chunk_ptrs`-pointer chunks with
    /// one gather buffer each. Followed by the gather (serial here,
    /// chunk-parallel in `CorePool`) and [`Self::route_finish`].
    pub(crate) fn route_prepare(&mut self, axon_in: &[u32], chunk_ptrs: usize) {
        debug_assert!(axon_in.windows(2).all(|w| w[0] < w[1]), "axon ids must be sorted");
        // STDP step 3: axon pre-traces advance with the route phase —
        // decay every trace once per step, then bump the axons
        // delivered this step (axons decay with tau_pre: they are
        // pre-synaptic only)
        if let Some(p) = self.plastic.as_deref_mut() {
            let tau = p.cfg.tau_pre;
            for tr in p.trace_axon.iter_mut() {
                *tr = decay_trace(*tr, tau);
            }
            for &a in axon_in {
                let tr = &mut p.trace_axon[a as usize];
                *tr = (*tr + TRACE_ONE).min(TRACE_CEIL);
            }
        }
        self.hbm.counters.bram_accesses += axon_in.len() as u64 + self.fired_buf.len() as u64;

        // ---- phase 1: pointer fetches
        let p0 = self.hbm.counters.pointer_rows;
        self.ptr_queue.clear();
        self.hbm.fetch_axon_pointers(axon_in, &mut self.ptr_queue);
        // neurons fetch in model-grouped pointer order for burst dedup
        self.fired_sorted.clear();
        self.fired_sorted.extend_from_slice(&self.fired_buf);
        let rows = &self.hbm.image.neuron_ptr_row;
        self.fired_sorted.sort_unstable_by_key(|&i| (rows[i as usize], i));
        self.hbm.fetch_neuron_pointers(&self.fired_sorted, &mut self.ptr_queue);
        self.route_ptr_rows = self.hbm.counters.pointer_rows - p0;

        // ---- chunk geometry: one gather buffer per pointer chunk
        self.route_chunk_ptrs = chunk_ptrs.max(1);
        self.route_chunks = self.ptr_queue.len().div_ceil(self.route_chunk_ptrs);
        if self.gather_bufs.len() < self.route_chunks {
            self.gather_bufs.resize_with(self.route_chunks, Vec::new);
        }
    }

    /// Raw route state for the pool's chunk-parallel gather; call
    /// between [`Self::route_prepare`] and [`Self::route_finish`].
    /// Workers drive each chunk through the same [`gather_chunk`] the
    /// serial path uses.
    pub(crate) fn route_view(&mut self) -> RouteView<B> {
        RouteView {
            image: &self.hbm.image,
            backend: &self.backend,
            ptrs: self.ptr_queue.as_ptr(),
            n_ptrs: self.ptr_queue.len(),
            bufs: self.gather_bufs.as_mut_ptr(),
            n_chunks: self.route_chunks,
            chunk_ptrs: self.route_chunk_ptrs,
        }
    }

    /// Route-phase epilogue: access/cycle accounting (reconstructed from
    /// the pointer queue and buffer lengths — bit-identical totals to
    /// the serial per-region counting), the ordered merge/accumulate of
    /// the per-chunk buffers (ascending chunk index == serial event
    /// order), and the output-spike scan.
    pub(crate) fn route_finish(&mut self) -> anyhow::Result<()> {
        let rows: u64 = self.ptr_queue.iter().map(|p| p.rows as u64).sum();
        self.hbm.counters.synapse_rows += rows;
        let bufs = &self.gather_bufs[..self.route_chunks];
        self.hbm.counters.events += bufs.iter().map(|b| b.len() as u64).sum::<u64>();
        self.cycles += self.hbm.phase_cycles(self.route_ptr_rows, rows);

        // ---- phase 4: fused accumulate over the ordered buffer list
        self.backend.accumulate_bufs(&mut self.v, bufs)?;

        // ---- STDP steps 5-6: weight mutation, serial, after the
        // ordered merge — deliveries above used end-of-previous-step
        // weights (gathered in phase 2), and the chunk-merge
        // determinism contract is untouched. Depression first (fired
        // sources' outgoing plastic slots, via the pointer queue — one
        // region per fired source, regions disjoint), then
        // potentiation (fired neurons' incoming plastic slots, via the
        // reverse index). Deltas are per-slot and additive, so
        // traversal order never changes a weight's value.
        if let Some(p) = self.plastic.as_deref_mut() {
            let PlasticState { cfg, trace_pre, trace_post, trace_axon, in_edges, events } = p;
            let cfg = *cfg;
            let image = &mut self.hbm.image;
            for ptr in &self.ptr_queue {
                for r in ptr.start_row..ptr.start_row + ptr.rows {
                    let mut m = image.row_mask[r as usize];
                    let row = &mut image.syn_rows[r as usize];
                    while m != 0 {
                        let slot = m.trailing_zeros() as usize;
                        m &= m - 1;
                        let e = &mut row[slot];
                        let d = stdp_delta(cfg.a_minus, trace_post[e.target as usize]);
                        e.weight = apply_delta(e.weight, -d, &cfg);
                        *events += 1;
                    }
                }
            }
            for &post in &self.fired_buf {
                for edge in &in_edges[post as usize] {
                    let tr = if edge.axon_src {
                        trace_axon[edge.src as usize]
                    } else {
                        trace_pre[edge.src as usize]
                    };
                    let d = stdp_delta(cfg.a_plus, tr);
                    let e = &mut image.syn_rows[edge.row as usize][edge.slot as usize];
                    e.weight = apply_delta(e.weight, d, &cfg);
                    *events += 1;
                }
            }
        }

        // outputs
        self.out_buf.clear();
        for &i in &self.fired_buf {
            if self.is_output[i as usize] {
                self.out_buf.push(i);
            }
        }
        Ok(())
    }

    /// Output-neuron spikes from the last completed step.
    pub fn output_spikes(&self) -> &[u32] {
        &self.out_buf
    }
}

/// Gather one pointer chunk of a prepared route queue into the chunk's
/// buffer: clear it, then stream pointers `[c*K, (c+1)*K).min(len)`
/// through [`UpdateBackend::gather`]. This is **the** single chunk
/// implementation — the serial [`CoreEngine::phase_route`] and the
/// pool's `run_route_chunk` both call it, so chunk boundary math and
/// the clear policy cannot diverge between serial and pooled routing.
pub(crate) fn gather_chunk<B: UpdateBackend>(
    image: &HbmImage,
    backend: &B,
    queue: &[Pointer],
    chunk: usize,
    chunk_ptrs: usize,
    buf: &mut Vec<(u32, i32)>,
) {
    buf.clear();
    let lo = chunk.saturating_mul(chunk_ptrs).min(queue.len());
    let hi = lo.saturating_add(chunk_ptrs).min(queue.len());
    for &ptr in &queue[lo..hi] {
        backend.gather(image, ptr, buf);
    }
}

impl<B: UpdateBackend> CoreEngine<B> {
    /// Read membrane potentials (paper `read_membrane`).
    pub fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        ids.iter().map(|&i| self.v[i as usize]).collect()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Resolve a source's synapse region, or error on a bad id.
    fn source_region(&self, pre_is_axon: bool, pre: u32) -> anyhow::Result<Pointer> {
        let table =
            if pre_is_axon { &self.hbm.image.axon_ptr } else { &self.hbm.image.neuron_ptr };
        table.get(pre as usize).copied().ok_or_else(|| {
            anyhow::anyhow!(
                "synapse source out of range: {} {pre} (have {})",
                if pre_is_axon { "axon" } else { "neuron" },
                table.len()
            )
        })
    }

    /// A region entry counts as a **live** synapse `pre -> post` iff it
    /// is valid, targets `post`, and is distinguishable from the
    /// compiler's dummy padding (valid, target 0, weight 0, mask
    /// clear). The one ambiguous corner — a compile-time zero-weight
    /// synapse onto neuron 0 — is treated as absent by live edits; the
    /// journal/compaction path preserves it exactly.
    #[inline]
    fn entry_live(e: &SynEntry, mask: u16, slot: usize, post: u32) -> bool {
        e.is_valid() && e.target == post && (post != 0 || e.weight != 0 || mask & (1 << slot) != 0)
    }

    /// Live in-place weight edit on the compiled image — the engine
    /// half of `Simulator::write_synapse`. Sets **every** duplicate
    /// slot of `pre -> post` to `weight`; membranes, traces and all
    /// other weights are untouched. Setting a non-zero weight (re-)arms
    /// the slot for delivery and plasticity; writing zero keeps the
    /// slot armed so it can learn back (row-mask policy of
    /// `crate::plasticity`). Returns false when the synapse does not
    /// exist (callers fall back to [`Self::add_synapse`]).
    pub fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> anyhow::Result<bool> {
        let ptr = self.source_region(pre_is_axon, pre)?;
        if post as usize >= self.hbm.image.n_neurons {
            anyhow::bail!("synapse target out of range: {post}");
        }
        let mut plastic = self.plastic.as_deref_mut();
        let image = &mut self.hbm.image;
        let slot = image.slot_of[post as usize] as usize;
        let mut matched = false;
        for r in ptr.start_row..ptr.start_row + ptr.rows {
            let mask = image.row_mask[r as usize];
            let e = &mut image.syn_rows[r as usize][slot];
            if Self::entry_live(e, mask, slot, post) {
                e.weight = weight;
                if weight != 0 && mask & (1 << slot) == 0 {
                    image.row_mask[r as usize] |= 1 << slot;
                    if let Some(p) = plastic.as_deref_mut() {
                        p.note_install(r, slot as u8, pre_is_axon, pre, post);
                    }
                }
                matched = true;
            }
        }
        Ok(matched)
    }

    /// Read a synapse weight off the live image (first duplicate slot),
    /// or None when absent / out of range.
    pub fn read_synapse(&self, pre_is_axon: bool, pre: u32, post: u32) -> Option<i16> {
        let ptr = self.source_region(pre_is_axon, pre).ok()?;
        let image = &self.hbm.image;
        if post as usize >= image.n_neurons {
            return None;
        }
        let slot = image.slot_of[post as usize] as usize;
        for r in ptr.start_row..ptr.start_row + ptr.rows {
            let e = &image.syn_rows[r as usize][slot];
            if Self::entry_live(e, image.row_mask[r as usize], slot, post) {
                return Some(e.weight);
            }
        }
        None
    }

    /// Live structural edit: install a new synapse into a free slot of
    /// the source's existing region (dummy padding is reusable).
    /// Upserts — when the synapse already exists this is exactly
    /// [`Self::write_synapse`] and returns Ok(false); returns Ok(true)
    /// when a slot was created. Errors when the region has no free row
    /// at the target's slot: the image needs a journal compaction +
    /// rebuild (the facade surfaces this as a config error).
    pub fn add_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> anyhow::Result<bool> {
        if self.write_synapse(pre_is_axon, pre, post, weight)? {
            return Ok(false);
        }
        let ptr = self.source_region(pre_is_axon, pre)?;
        let mut plastic = self.plastic.as_deref_mut();
        let image = &mut self.hbm.image;
        let slot = image.slot_of[post as usize] as usize;
        for r in ptr.start_row..ptr.start_row + ptr.rows {
            let mask = image.row_mask[r as usize];
            let e = &mut image.syn_rows[r as usize][slot];
            // free = never valid, or dead weight-0 padding (mask clear)
            let free = !e.is_valid() || (e.weight == 0 && mask & (1 << slot) == 0);
            if free {
                *e = SynEntry { target: post, weight, flags: SYN_VALID };
                if weight != 0 {
                    image.row_mask[r as usize] |= 1 << slot;
                    if let Some(p) = plastic.as_deref_mut() {
                        p.note_install(r, slot as u8, pre_is_axon, pre, post);
                    }
                }
                return Ok(true);
            }
        }
        anyhow::bail!(
            "no free HBM slot for synapse {} {pre} -> {post}: journal compaction required",
            if pre_is_axon { "axon" } else { "neuron" },
        )
    }

    /// Live structural edit: remove every duplicate slot of
    /// `pre -> post` from the image (slots are cleared and disarmed —
    /// physically reclaimed at the next journal compaction). Returns
    /// the number of slots removed.
    pub fn remove_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
    ) -> anyhow::Result<usize> {
        let ptr = self.source_region(pre_is_axon, pre)?;
        if post as usize >= self.hbm.image.n_neurons {
            anyhow::bail!("synapse target out of range: {post}");
        }
        let mut plastic = self.plastic.as_deref_mut();
        let image = &mut self.hbm.image;
        let slot = image.slot_of[post as usize] as usize;
        let mut removed = 0;
        for r in ptr.start_row..ptr.start_row + ptr.rows {
            let mask = image.row_mask[r as usize];
            let e = &mut image.syn_rows[r as usize][slot];
            if Self::entry_live(e, mask, slot, post) {
                *e = SynEntry::default();
                image.row_mask[r as usize] &= !(1 << slot);
                if let Some(p) = plastic.as_deref_mut() {
                    p.note_remove(r, slot as u8, post);
                }
                removed += 1;
            }
        }
        Ok(removed)
    }
}

// ---- facade adapter -------------------------------------------------------

use crate::sim::{BatchResult, CostSummary, SimError, Simulator, StepResult};

/// The event-driven core as a [`Simulator`] session (backends `rust`
/// and `xla` of the facade). Inherent methods keep precedence for
/// in-crate callers; external code only sees the trait surface.
impl<B: UpdateBackend> Simulator for CoreEngine<B> {
    fn step(&mut self, axon_in: &[u32]) -> Result<StepResult<'_>, SimError> {
        crate::sim::check_axons(axon_in, self.hbm.image.axon_ptr_row.len())?;
        CoreEngine::step(self, axon_in)?;
        Ok(StepResult { fired: &self.fired_buf, output_spikes: &self.out_buf })
    }

    /// Batched override: one stimulus marshal (range validation) for the
    /// whole batch, then the inherent per-step loop with the per-step
    /// re-check skipped. Bit-identical to the default `step` loop.
    fn step_many(&mut self, batch: &[Vec<u32>]) -> Result<BatchResult, SimError> {
        let n_axons = self.hbm.image.axon_ptr_row.len();
        for axons in batch {
            crate::sim::check_axons(axons, n_axons)?;
        }
        let mut result = BatchResult { spikes: Vec::with_capacity(batch.len()), fired_total: 0 };
        for axons in batch {
            let out = CoreEngine::step(self, axons)?;
            result.fired_total += out.fired.len() as u64;
            result.spikes.push(out.output_spikes.to_vec());
        }
        Ok(result)
    }

    fn fired(&self) -> &[u32] {
        &self.fired_buf
    }

    fn output_spikes(&self) -> &[u32] {
        &self.out_buf
    }

    fn reset(&mut self) {
        CoreEngine::reset(self);
    }

    fn reset_cost(&mut self) {
        CoreEngine::reset_cost(self);
    }

    fn read_membrane(&self, ids: &[u32]) -> Vec<i32> {
        CoreEngine::read_membrane(self, ids)
    }

    fn cost(&self, model: &EnergyModel) -> CostSummary {
        CoreEngine::cost(self, model).into()
    }

    fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    fn n_neurons(&self) -> usize {
        self.v.len()
    }

    fn n_axons(&self) -> usize {
        self.hbm.image.axon_ptr_row.len()
    }

    fn hbm_stats(&self) -> Option<crate::hbm::LayoutStats> {
        Some(self.hbm.image.stats)
    }

    fn write_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        CoreEngine::write_synapse(self, pre_is_axon, pre, post, weight)
            .map_err(|e| SimError::Config(e.to_string()))
    }

    fn read_synapse(
        &self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
    ) -> Result<Option<i16>, SimError> {
        Ok(CoreEngine::read_synapse(self, pre_is_axon, pre, post))
    }

    fn add_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
        weight: i16,
    ) -> Result<bool, SimError> {
        CoreEngine::add_synapse(self, pre_is_axon, pre, post, weight)
            .map_err(|e| SimError::Config(e.to_string()))
    }

    fn remove_synapse(
        &mut self,
        pre_is_axon: bool,
        pre: u32,
        post: u32,
    ) -> Result<usize, SimError> {
        CoreEngine::remove_synapse(self, pre_is_axon, pre, post)
            .map_err(|e| SimError::Config(e.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::backend::RustBackend;
    use crate::engine::dense::DenseEngine;
    use crate::snn::{Network, NetworkBuilder, NeuronModel};
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    fn random_net(rng: &mut Xorshift32, n: usize, a: usize, p: f64) -> Network {
        let models = [
            NeuronModel::if_neuron(rng.range_i32(5, 50)),
            NeuronModel::lif(rng.range_i32(5, 50), -6, 3, true).unwrap(),
            NeuronModel::ann(rng.range_i32(2, 30), 0, false).unwrap(),
        ];
        let mut b = NetworkBuilder::new();
        let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
        for i in 0..n {
            let mut syns = Vec::new();
            for t in 0..n {
                if rng.chance(p) {
                    syns.push((keys[t].clone(), rng.range_i32(-60, 60)));
                }
            }
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_neuron(&keys[i], models[rng.below(3) as usize], &refs).unwrap();
        }
        for i in 0..a {
            let mut syns = Vec::new();
            for t in 0..n {
                if rng.chance(p * 2.0) {
                    syns.push((keys[t].clone(), rng.range_i32(-60, 60)));
                }
            }
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_axon(&format!("a{i}"), &refs).unwrap();
        }
        for i in 0..n {
            if rng.chance(0.3) {
                b.add_output(&keys[i]);
            }
        }
        b.build().unwrap().0.clone_with_seed(rng.next_u32())
    }

    impl Network {
        fn clone_with_seed(mut self, seed: u32) -> Self {
            self.base_seed = seed;
            self
        }
    }

    #[test]
    fn prop_event_engine_matches_dense_engine() {
        ptest::check("core_vs_dense_parity", 25, |rng| {
            let n = rng.below(60) as usize + 4;
            let a = rng.below(12) as usize + 1;
            let net = random_net(rng, n, a, 0.12);
            let mut dense = DenseEngine::new(&net);
            let mut core =
                CoreEngine::new(&net, SlotStrategy::BalanceFanIn, RustBackend).unwrap();
            for _t in 0..15 {
                let axons: Vec<u32> =
                    (0..a as u32).filter(|_| rng.chance(0.4)).collect();
                dense.step(&axons);
                let dense_fired = dense.fired();
                let out = core.step(&axons).map_err(|e| e.to_string())?;
                ptest::prop_assert_eq(out.fired.to_vec(), dense_fired, "fired")?;
                ptest::prop_assert_eq(core.v.clone(), dense.v.clone(), "membranes")?;
            }
            Ok(())
        });
    }

    #[test]
    fn counters_increase_with_activity() {
        let mut rng = Xorshift32::new(11);
        let net = random_net(&mut rng, 50, 4, 0.2);
        let mut core = CoreEngine::new(&net, SlotStrategy::Modulo, RustBackend).unwrap();
        core.step(&[0, 1, 2, 3]).unwrap();
        let after_active = core.counters().hbm_rows();
        assert!(after_active > 0);
        assert!(core.cycles > 0);
        // URAM swept regardless of activity
        assert_eq!(core.counters().uram_accesses, 2 * 50);
    }

    #[test]
    fn idle_step_costs_only_sweep() {
        let m = NeuronModel::if_neuron(1 << 20);
        let mut b = NetworkBuilder::new();
        for i in 0..32 {
            b.add_neuron(&format!("n{i}"), m, &[]).unwrap();
        }
        b.add_axon("a0", &[("n0", 1)]).unwrap();
        let net = b.build().unwrap().0;
        let mut core = CoreEngine::new(&net, SlotStrategy::Modulo, RustBackend).unwrap();
        core.step(&[]).unwrap();
        assert_eq!(core.counters().hbm_rows(), 0, "no spikes -> no HBM traffic");
        assert_eq!(core.cycles, core.hbm.update_cycles());
    }

    /// Satellite regression: standalone `phase_update` calls used to
    /// replay the same noise seed because `step_num` only advanced in
    /// `phase_route`. The seed now advances with the sweep; `step()` keeps
    /// the exact same seed schedule.
    #[test]
    fn standalone_phase_update_draws_fresh_noise() {
        use crate::util::prng::{mix_seed, noise17, shift_noise};
        let k = 10usize;
        let m = NeuronModel::lif(i32::MAX, 0, 63, true).unwrap(); // never fires, ~no leak
        let mut b = NetworkBuilder::new().seed(77);
        for i in 0..k {
            b.add_neuron(&format!("n{i}"), m, &[]).unwrap();
        }
        let net = b.build().unwrap().0;

        let mut e = CoreEngine::new(&net, SlotStrategy::Modulo, RustBackend).unwrap();
        e.phase_update().unwrap();
        let v1 = e.v.clone();
        e.phase_update().unwrap();
        let v2 = e.v.clone();

        // expected trajectory: sweep t draws noise17(mix_seed(seed, t), i)
        let leak = |x: i32| x - (x >> 31); // lam 63 clamps to 31
        let noisy = |x: i32, t: u32, i: usize| {
            leak(x.wrapping_add(shift_noise(noise17(mix_seed(77, t), i as u32), 0)))
        };
        let want1: Vec<i32> = (0..k).map(|i| noisy(0, 0, i)).collect();
        let want2: Vec<i32> = (0..k).map(|i| noisy(want1[i], 1, i)).collect();
        assert_eq!(v1, want1, "first standalone sweep");
        assert_eq!(v2, want2, "second standalone sweep must use the NEXT seed");
        // the pre-fix behaviour (seed 0 replayed) must no longer occur
        let replay: Vec<i32> = (0..k).map(|i| noisy(want1[i], 0, i)).collect();
        assert_ne!(v2, replay, "noise seed was replayed across standalone sweeps");

        // step() keeps the identical seed schedule (bit-exact contract)
        let mut es = CoreEngine::new(&net, SlotStrategy::Modulo, RustBackend).unwrap();
        es.step(&[]).unwrap();
        es.step(&[]).unwrap();
        assert_eq!(es.v, v2, "step() seed schedule changed");
    }

    #[test]
    fn output_spikes_subset_of_fired() {
        let mut rng = Xorshift32::new(3);
        let net = random_net(&mut rng, 40, 4, 0.2);
        let outputs = net.outputs.clone();
        let mut core = CoreEngine::new(&net, SlotStrategy::Modulo, RustBackend).unwrap();
        for t in 0..10u32 {
            let axons: Vec<u32> = if t % 2 == 0 { vec![0, 2] } else { vec![] };
            let out = core.step(&axons).unwrap();
            for s in out.output_spikes {
                assert!(out.fired.contains(s));
                assert!(outputs.contains(s));
            }
        }
    }
}
