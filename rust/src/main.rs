//! `hiaer-spike` — the leader/coordinator CLI.
//!
//! Subcommands:
//!   info    <net.hsn>                 network + HBM layout summary
//!   run     <net.hsn> <stimulus.txt>  execute a network on the cluster sim
//!   convert <model.hsl> <out.hsn>     PyTorch layer graph -> network
//!   serve   <spool-dir>               NSG-style job daemon (poll a dir)
//!   serve   --listen <addr>           shared multi-session TCP server
//!   serve-session                     JSON-lines session protocol on stdio
//!   shard-worker                      one shard of a sharded session
//!                                     (spawned by the parent, not users)
//!   bench-step <net.hsn>              steps/s of the hot loop
//!
//! Every execution path goes through the unified `sim` facade: the
//! shared deployment flags (--servers/--fpgas/--cores, --strategy,
//! --backend, --seed, --artifacts) are parsed once by
//! `SimOptions::from_args` and become a `SimConfig`.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use hiaer_spike::cluster::{run_job, Job, JobQueue, JobStatus};
use hiaer_spike::cluster::parse_stimulus;
use hiaer_spike::convert::{convert, BiasMode};
use hiaer_spike::energy::EnergyModel;
use hiaer_spike::hbm::HbmImage;
use hiaer_spike::model_fmt::{hsl::read_hsl, read_hsn, write_hsn};
use hiaer_spike::sim::{Backend, SimOptions, Simulator};
use hiaer_spike::util::cli::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let args = Args::parse_env(&["verbose", "xla", "help", "once"]).map_err(|e| anyhow!(e))?;
    if args.flag("help") || args.positional.is_empty() {
        print_help();
        return Ok(());
    }
    match args.positional[0].as_str() {
        "info" => cmd_info(&args),
        "run" => cmd_run(&args),
        "convert" => cmd_convert(&args),
        "serve" => cmd_serve(&args),
        "serve-session" => cmd_serve_session(&args),
        // internal: one shard subprocess of a Backend::Sharded session
        // (binary AER frames on stdin/stdout; see cluster::shard docs)
        "shard-worker" => hiaer_spike::cluster::shard::shard_worker_main(&args),
        "bench-step" => cmd_bench_step(&args),
        other => bail!("unknown subcommand {other:?} (try --help)"),
    }
}

fn print_help() {
    println!(
        "hiaer-spike — event-driven neuromorphic platform (simulated substrate)\n\
         \n\
         USAGE: hiaer-spike <subcommand> [options]\n\
         \n\
         SUBCOMMANDS\n\
           info <net.hsn>                  network + HBM layout summary\n\
           run <net.hsn> <stimulus.txt>    execute on the cluster simulator\n\
           convert <model.hsl> <out.hsn>   layer graph -> network (Supp A.2)\n\
           serve <spool-dir>               job daemon: runs <id>.job files\n\
           serve --listen <host:port>      shared TCP server: many concurrent\n\
                                           JSON-lines sessions with admission\n\
                                           control, quotas, deadlines, panic\n\
                                           isolation and graceful SIGTERM\n\
                                           drain (port 0 = ephemeral; the\n\
                                           bound address is printed first)\n\
           serve-session                   JSON-lines session protocol on\n\
                                           stdin/stdout (the hs_api\n\
                                           backend=\"rust\" transport; see\n\
                                           sim::session docs for the wire\n\
                                           format)\n\
           bench-step <net.hsn>            hot-loop steps/s\n\
         \n\
         OPTIONS (shared deployment flags — any execution subcommand)\n\
           --servers N --fpgas N --cores N   topology (default 1/1/1)\n\
           --strategy modulo|balance         HBM slot assignment (default balance)\n\
           --backend dense|rust|pool|xla|sharded\n\
                                             execution backend (default rust;\n\
                                             xla needs --features pjrt)\n\
           --seed N                          override the network noise seed\n\
           --workers N                       worker threads for the pooled\n\
                                             backends (>= 1; default: available\n\
                                             parallelism; bit-exactness is\n\
                                             worker-count-invariant)\n\
           --shards N                        shard subprocesses for the sharded\n\
                                             backend (implies --backend sharded;\n\
                                             >= 1, <= cores; default min(2,\n\
                                             cores); spike trains are\n\
                                             shard-count-invariant)\n\
           --shard-timeout-ms N              per-frame deadline on shard\n\
                                             subprocess reads (default 30s)\n\
           --route core|chunk                route-phase granularity (default\n\
                                             chunk: gather spread over workers)\n\
           --learn AP,AM,TPRE,TPOST          switch on pair-based STDP (A+/A-\n\
                                             amplitudes, trace tau shifts);\n\
                                             event-driven backends only\n\
           --learn-clamp MIN,MAX             learned-weight clamp (default full\n\
                                             i16 range; requires --learn)\n\
           --artifacts DIR                   AOT artifact dir (default artifacts/)\n\
         \n\
         OPTIONS (subcommand-specific)\n\
           --steps N                         steps for bench-step (default 1000)\n\
           --bias threshold|axon             converter bias mode\n\
           --jobs N                          serve: parallel jobs (default 2)\n\
           --once                            serve: single spool pass, then exit\n\
         \n\
         OPTIONS (serve --listen — serving-tier limits)\n\
           --max-sessions N                  concurrent sessions (default 32)\n\
           --concurrency N                   shared compute permits (default:\n\
                                             available parallelism)\n\
           --max-neurons N                   per-session net-size quota\n\
           --max-batch N                     per-session step_many quota\n\
           --max-edits-per-step N            per-session write_synapse budget\n\
                                             between step intervals\n\
           --max-line-bytes N                request-line byte cap (default 8 MiB)\n\
           --max-frame-bytes N               binary-wire frame byte cap (wire v2;\n\
                                             default 256 MiB; sessions opt in\n\
                                             with \"wire\":\"binary\" at configure)\n\
           --request-timeout-ms N            compute-permit deadline (default 30s)\n\
           --idle-timeout-ms N               idle-session eviction TTL (default 5m)\n\
           --max-errors N                    protocol-error flood eviction\n\
                                             threshold (default 64)\n\
           --drain-grace-ms N                drain patience on SIGTERM (default 30s)"
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    let path = args.positional.get(1).context("info: missing <net.hsn>")?;
    let net = read_hsn(path)?;
    let opts = SimOptions::from_args(args)?;
    let image = HbmImage::compile(&net, opts.strategy)?;
    println!("network {path}");
    println!("  neurons:  {}", net.n_neurons());
    println!("  axons:    {}", net.n_axons());
    println!("  synapses: {}", net.n_synapses());
    println!("  outputs:  {}", net.outputs.len());
    println!("  models:   {}", image.models.len());
    println!("hbm layout ({:?})", opts.strategy);
    println!("  synapse rows:    {}", image.stats.synapse_rows);
    println!("  packing density: {:.3}", image.stats.packing_density);
    println!("  dummy slots:     {}", image.stats.dummy_slots);
    println!("  total bytes:     {}", image.stats.total_bytes);
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let net_path = args.positional.get(1).context("run: missing <net.hsn>")?;
    let stim_path = args.positional.get(2).context("run: missing <stimulus.txt>")?;
    let stim_text =
        std::fs::read_to_string(stim_path).with_context(|| format!("reading {stim_path}"))?;
    let stimulus = parse_stimulus(&stim_text)?;
    let options = SimOptions::from_args(args)?;
    let job = Job { id: 0, net_path: PathBuf::from(net_path), stimulus, options };
    let r = run_job(&job, &EnergyModel::default());
    match r.status {
        JobStatus::Done => {
            for (t, spikes) in r.spikes.iter().enumerate() {
                if !spikes.is_empty() {
                    let ids: Vec<String> = spikes.iter().map(|s| s.to_string()).collect();
                    println!("t={t}: {}", ids.join(" "));
                }
            }
            println!("# energy_uj={:.3} latency_us={:.3}", r.energy_uj, r.latency_us);
            Ok(())
        }
        s => bail!("job failed: {s:?}"),
    }
}

fn cmd_convert(args: &Args) -> Result<()> {
    let hsl_path = args.positional.get(1).context("convert: missing <model.hsl>")?;
    let out_path = args.positional.get(2).context("convert: missing <out.hsn>")?;
    let bias = match args.get_or("bias", "threshold") {
        "threshold" => BiasMode::Threshold,
        "axon" => BiasMode::Axon,
        s => bail!("bad --bias {s:?} (options: threshold, axon)"),
    };
    let seed = args.get_u32("seed", 0).map_err(|e| anyhow!(e))?;
    let graph = read_hsl(hsl_path)?;
    let t0 = Instant::now();
    let conv = convert(&graph, bias, seed)?;
    write_hsn(&conv.net, out_path)?;
    println!(
        "converted {} -> {} ({} neurons, {} synapses, {} input axons, T={}) in {:.2?}",
        hsl_path,
        out_path,
        conv.net.n_neurons(),
        conv.net.n_synapses(),
        conv.n_input_axons,
        conv.timesteps,
        t0.elapsed()
    );
    Ok(())
}

/// serve: two modes sharing the deployment flags.
///
/// `serve --listen <host:port>` — the shared multi-session TCP server
/// (`sim::serve`): many concurrent JSON-lines sessions with admission
/// control, per-session quotas, request deadlines, panic isolation,
/// idle eviction and graceful drain on SIGTERM/SIGINT. The bound
/// address is printed on stdout first (so `--listen 127.0.0.1:0` works
/// for scripted/ephemeral deployments).
///
/// `serve <spool-dir>` — the NSG-style spool daemon: poll for
/// `<name>.job` files (line 1: path to .hsn; rest: stimulus lines) and
/// write `<name>.result` next to them.
fn cmd_serve(args: &Args) -> Result<()> {
    if let Some(addr) = args.get("listen") {
        let opts = SimOptions::from_args(args)?;
        let limits = hiaer_spike::sim::serve::ServeLimits::from_args(args).map_err(|e| anyhow!(e))?;
        let listener = std::net::TcpListener::bind(addr)
            .with_context(|| format!("binding {addr}"))?;
        println!("listening on {}", listener.local_addr()?);
        // line-buffered stdout under a pipe: flush so smoke scripts
        // waiting for the address line don't deadlock
        use std::io::Write as _;
        std::io::stdout().flush().ok();
        hiaer_spike::sim::serve::install_drain_signal_handler();
        let shutdown = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        hiaer_spike::sim::serve::serve_tcp(listener, opts, limits, shutdown)?;
        println!("drained; all sessions closed");
        return Ok(());
    }
    let spool = args.positional.get(1).context("serve: missing <spool-dir>")?;
    let spool = Path::new(spool);
    std::fs::create_dir_all(spool)?;
    // `--jobs` sizes the job queue; `--workers` (a shared deployment
    // flag) sizes each job's simulator worker pool. Flag-rename guard:
    // `serve --workers` used to mean job slots — warn instead of
    // silently dropping an old deployment to the 2-job default.
    if args.get("workers").is_some() && args.get("jobs").is_none() {
        eprintln!(
            "warning: `--workers` now sets each job's simulator worker pool \
             (shared deployment flag); serve's parallel job slots are `--jobs N` \
             (currently defaulting to 2)"
        );
    }
    let jobs = args.get_usize("jobs", 2).map_err(|e| anyhow!(e))?;
    let options = SimOptions::from_args(args)?;
    let queue = JobQueue::start(jobs, EnergyModel::default());
    println!("serving spool {} with {jobs} job workers", spool.display());
    let mut next_id = 0u64;
    let mut names: std::collections::HashMap<u64, String> = Default::default();
    loop {
        let mut submitted = false;
        let mut entries: Vec<PathBuf> = std::fs::read_dir(spool)?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().map(|e| e == "job") == Some(true))
            .collect();
        entries.sort();
        for path in entries {
            let text = std::fs::read_to_string(&path)?;
            let mut lines = text.lines();
            let net_path = lines.next().context("empty job file")?.trim().to_string();
            let stim_text: String = lines.map(|l| format!("{l}\n")).collect();
            let stimulus = parse_stimulus(&stim_text)?;
            let id = next_id;
            next_id += 1;
            names.insert(
                id,
                path.file_stem().unwrap_or_default().to_string_lossy().to_string(),
            );
            queue.submit(Job {
                id,
                net_path: PathBuf::from(net_path),
                stimulus,
                options: options.clone(),
            });
            std::fs::rename(&path, path.with_extension("taken"))?;
            submitted = true;
        }
        if submitted {
            for r in queue.drain() {
                let name = names.get(&r.id).cloned().unwrap_or_else(|| r.id.to_string());
                let out = spool.join(format!("{name}.result"));
                let mut text = format!("status: {:?}\n", r.status);
                for (t, s) in r.spikes.iter().enumerate() {
                    let ids: Vec<String> = s.iter().map(|x| x.to_string()).collect();
                    text.push_str(&format!("t={t}: {}\n", ids.join(" ")));
                }
                text.push_str(&format!(
                    "energy_uj: {:.3}\nlatency_us: {:.3}\n",
                    r.energy_uj, r.latency_us
                ));
                std::fs::write(out, text)?;
            }
        }
        if args.flag("once") {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(200));
    }
    // every pass drains before looping, so no results can be pending here
    let _ = queue.shutdown();
    Ok(())
}

/// serve-session: drive one `Simulator` session over the line-delimited
/// JSON protocol on stdin/stdout. Deployment flags (`--backend`,
/// topology, `--strategy`, `--seed`, ...) fix the session's options; the
/// client's `configure` request supplies the network. See
/// `hiaer_spike::sim::session` for the wire format.
fn cmd_serve_session(args: &Args) -> Result<()> {
    let opts = SimOptions::from_args(args)?;
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    hiaer_spike::sim::session::serve(opts, stdin.lock(), &mut stdout.lock())?;
    Ok(())
}

fn cmd_bench_step(args: &Args) -> Result<()> {
    let net_path = args.positional.get(1).context("bench-step: missing <net.hsn>")?;
    let steps = args.get_usize("steps", 1000).map_err(|e| anyhow!(e))?;
    let net = read_hsn(net_path)?;
    let opts = SimOptions::from_args(args)?;
    let axons: Vec<u32> = (0..net.n_axons() as u32).step_by(2).collect();

    // primary engine: the selected backend on a single core
    let mut single = opts.clone();
    single.topology = hiaer_spike::partition::ClusterTopology::single_core();
    if single.backend == Backend::Sharded {
        single.shards = Some(1); // one core supports exactly one shard
    }
    let mut sim = single.into_config(net.clone()).build()?;
    let t0 = Instant::now();
    for _ in 0..steps {
        sim.step(&axons)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let cost = sim.cost(&EnergyModel::default());
    println!(
        "{steps} steps in {dt:.3}s = {:.0} steps/s, {:.0} synaptic events/s \
         (backend={}, sim cycles={})",
        steps as f64 / dt,
        cost.events as f64 / dt,
        sim.backend_name(),
        cost.cycles,
    );

    // topology-aware path when the requested topology has > 1 core
    if opts.topology.n_cores() > 1 {
        let sharded = opts.backend == Backend::Sharded;
        let mut cluster_opts = opts.clone();
        cluster_opts.backend = Backend::Rust;
        cluster_opts.shards = None;
        let mut mc = cluster_opts.into_config(net.clone()).build()?;
        let t0 = Instant::now();
        for _ in 0..steps {
            mc.step(&axons)?;
        }
        let dt = t0.elapsed().as_secs_f64();
        let used = mc.placement().map(|p| p.n_used_cores()).unwrap_or(mc.n_cores());
        println!("multicore ({used} cores): {:.0} steps/s", steps as f64 / dt);

        // sharded path: the same topology split over worker subprocesses
        if sharded {
            let n_shards =
                opts.shards.unwrap_or_else(|| opts.topology.n_cores().min(2));
            let mut sh = opts.into_config(net).build()?;
            let t0 = Instant::now();
            for _ in 0..steps {
                sh.step(&axons)?;
            }
            let dt = t0.elapsed().as_secs_f64();
            println!("sharded ({n_shards} shards): {:.0} steps/s", steps as f64 / dt);
        }
    }
    Ok(())
}
