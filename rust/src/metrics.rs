//! Run-level metrics aggregation: wall-clock throughput of the
//! coordinator, spike/event rates, and per-inference cost series used by
//! the benches to print the paper's mean±SD rows.

use std::time::Instant;

use crate::sim::CostSummary;
use crate::util::stats::mean_std;

/// Aggregates per-inference cost summaries into the Table-2 style summary.
#[derive(Clone, Debug, Default)]
pub struct CostSeries {
    pub energy_uj: Vec<f64>,
    pub latency_us: Vec<f64>,
    pub hbm_rows: Vec<f64>,
    pub events: Vec<f64>,
}

impl CostSeries {
    pub fn push(&mut self, r: &CostSummary) {
        self.energy_uj.push(r.energy_uj);
        self.latency_us.push(r.latency_us);
        self.hbm_rows.push(r.hbm_rows as f64);
        self.events.push(r.events as f64);
    }

    pub fn energy_mean_std(&self) -> (f64, f64) {
        mean_std(&self.energy_uj)
    }

    pub fn latency_mean_std(&self) -> (f64, f64) {
        mean_std(&self.latency_us)
    }

    pub fn len(&self) -> usize {
        self.energy_uj.len()
    }

    pub fn is_empty(&self) -> bool {
        self.energy_uj.is_empty()
    }
}

/// Wall-clock throughput meter for the coordinator hot path.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    pub items: u64,
    pub events: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Self { start: Instant::now(), items: 0, events: 0 }
    }

    pub fn record(&mut self, items: u64, events: u64) {
        self.items += items;
        self.events += events;
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn items_per_s(&self) -> f64 {
        self.items as f64 / self.elapsed_s().max(1e-12)
    }

    pub fn events_per_s(&self) -> f64 {
        self.events as f64 / self.elapsed_s().max(1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_series_stats() {
        let mut s = CostSeries::default();
        for e in [1.0, 2.0, 3.0] {
            s.push(&CostSummary { energy_uj: e, latency_us: e * 10.0, ..Default::default() });
        }
        let (m, _) = s.energy_mean_std();
        assert!((m - 2.0).abs() < 1e-12);
        let (ml, _) = s.latency_mean_std();
        assert!((ml - 20.0).abs() < 1e-12);
        assert_eq!(s.len(), 3);
    }

    #[test]
    fn throughput_counts() {
        let mut t = Throughput::new();
        t.record(10, 100);
        t.record(5, 50);
        assert_eq!(t.items, 15);
        assert_eq!(t.events, 150);
        assert!(t.items_per_s() > 0.0);
    }
}
