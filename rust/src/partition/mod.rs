//! Network partitioning and resource allocation (paper §3, ref [10]).
//!
//! Assigns every neuron to a core of the cluster (server / FPGA / core
//! hierarchy), subject to per-core neuron and synapse capacity, while
//! minimising *cut* synapses — events that must travel the slower
//! inter-core levels of the HiAER fabric. The strategy is the classic
//! two-phase: locality-preserving seeding (BFS order over the synaptic
//! graph from the axon roots) + greedy chunking, then a bounded
//! Kernighan-Lin-style refinement that migrates neurons whose gain
//! (external minus internal degree) is positive.

use crate::snn::NetView;

/// The physical hierarchy (paper: 5 compute servers x 8 FPGAs x 32 cores;
/// each FPGA targets 4M neurons / 1B synapses over its cores).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterTopology {
    pub servers: usize,
    pub fpgas_per_server: usize,
    pub cores_per_fpga: usize,
}

impl ClusterTopology {
    /// The full HiAER-Spike deployment at SDSC.
    pub const FULL: ClusterTopology =
        ClusterTopology { servers: 5, fpgas_per_server: 8, cores_per_fpga: 32 };

    pub fn single_core() -> Self {
        ClusterTopology { servers: 1, fpgas_per_server: 1, cores_per_fpga: 1 }
    }

    pub fn n_cores(&self) -> usize {
        self.servers * self.fpgas_per_server * self.cores_per_fpga
    }

    /// core id -> (server, fpga, core-within-fpga)
    pub fn locate(&self, core: usize) -> (usize, usize, usize) {
        let per_server = self.fpgas_per_server * self.cores_per_fpga;
        (core / per_server, (core % per_server) / self.cores_per_fpga, core % self.cores_per_fpga)
    }

    /// Routing level between two cores: 0 same core, 1 NoC (same FPGA),
    /// 2 FireFly (same server), 3 Ethernet.
    pub fn level(&self, a: usize, b: usize) -> u8 {
        if a == b {
            return 0;
        }
        let (sa, fa, _) = self.locate(a);
        let (sb, fb, _) = self.locate(b);
        if sa == sb && fa == fb {
            1
        } else if sa == sb {
            2
        } else {
            3
        }
    }
}

/// Per-core capacity limits (paper: 4M neurons / 1B synapses per FPGA
/// over 32 cores = 128K neurons / 32M synapses per core).
#[derive(Clone, Copy, Debug)]
pub struct CoreCapacity {
    pub max_neurons: usize,
    pub max_synapses: usize,
}

impl Default for CoreCapacity {
    fn default() -> Self {
        Self { max_neurons: 128 * 1024, max_synapses: 32 * 1024 * 1024 }
    }
}

/// A placement of the network onto the cluster.
#[derive(Clone, Debug)]
pub struct Partition {
    /// core id per neuron.
    pub core_of: Vec<u32>,
    /// neuron ids per core (ascending).
    pub members: Vec<Vec<u32>>,
    /// local index of each neuron within its core.
    pub local_of: Vec<u32>,
    pub topology: ClusterTopology,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CutStats {
    pub total_synapses: usize,
    pub cut_synapses: usize,
    /// cut synapses by routing level 1..=3
    pub by_level: [usize; 4],
}

impl Partition {
    /// Partition `net` over at most `topology.n_cores()` cores.
    /// Generic over the borrowed-CSR view ([`NetView`]): works identically
    /// on an owned `&Network` and an mmap-backed `.hsn` v2 file.
    pub fn compute<'a>(
        net: impl Into<NetView<'a>>,
        topology: ClusterTopology,
        cap: CoreCapacity,
    ) -> Result<Partition, String> {
        let net: NetView<'_> = net.into();
        let n = net.n_neurons();
        let n_cores = topology.n_cores();
        let syn_of: Vec<usize> = (0..n).map(|i| net.neuron_degree(i)).collect();

        // how many cores do we actually need?
        let total_syn: usize = syn_of.iter().sum();
        let need = (n.div_ceil(cap.max_neurons))
            .max(total_syn.div_ceil(cap.max_synapses.max(1)))
            .max(1);
        if need > n_cores {
            return Err(format!(
                "network needs >= {need} cores (n={n}, syn={total_syn}), topology has {n_cores}"
            ));
        }

        // ---- phase 1: seeding. Two candidate orders — BFS from the axon
        // roots (recovers locality when neuron ids are arbitrary) and
        // identity (optimal when the builder already laid out the network
        // layer-by-layer / block-by-block, as the model converter does).
        // Keep whichever cuts fewer synapses; ref [10]'s hierarchical
        // partitioner subsumes both.
        let per_core = n.div_ceil(need);
        let seed_with = |order: &[u32]| -> Result<(Vec<u32>, Vec<(usize, usize)>), String> {
            let mut core_of = vec![0u32; n];
            let mut counts = vec![(0usize, 0usize); n_cores];
            let mut core = 0usize;
            for &i in order {
                let s = syn_of[i as usize];
                while counts[core].0 + 1 > per_core.min(cap.max_neurons)
                    || counts[core].1 + s > cap.max_synapses
                {
                    core += 1;
                    if core >= n_cores {
                        return Err("capacity overflow during seeding".into());
                    }
                }
                core_of[i as usize] = core as u32;
                counts[core].0 += 1;
                counts[core].1 += s;
            }
            Ok((core_of, counts))
        };
        let cut_of = |core_of: &[u32]| -> usize {
            let mut cut = 0usize;
            for i in 0..n {
                for &t in net.neuron_targets(i) {
                    if core_of[i] != core_of[t as usize] {
                        cut += 1;
                    }
                }
            }
            cut
        };
        let identity: Vec<u32> = (0..n as u32).collect();
        let (id_core_of, id_counts) = seed_with(&identity)?;
        let (bfs_core_of, bfs_counts) = seed_with(&bfs_order(net))?;
        let (mut core_of, mut counts) = if cut_of(&id_core_of) <= cut_of(&bfs_core_of) {
            (id_core_of, id_counts)
        } else {
            (bfs_core_of, bfs_counts)
        };
        let used_cores = counts.iter().filter(|c| c.0 > 0).count();

        // ---- phase 2: bounded KL-style refinement
        if used_cores > 1 {
            refine(net, &mut core_of, &mut counts, cap, 2);
        }

        // ---- finalize
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); n_cores];
        for (i, &c) in core_of.iter().enumerate() {
            members[c as usize].push(i as u32);
        }
        let mut local_of = vec![0u32; n];
        for m in &members {
            for (li, &g) in m.iter().enumerate() {
                local_of[g as usize] = li as u32;
            }
        }
        Ok(Partition { core_of, members, local_of, topology })
    }

    pub fn n_used_cores(&self) -> usize {
        self.members.iter().filter(|m| !m.is_empty()).count()
    }

    /// Cut statistics under the topology's routing levels.
    pub fn cut_stats<'a>(&self, net: impl Into<NetView<'a>>) -> CutStats {
        let net: NetView<'_> = net.into();
        let mut s = CutStats::default();
        for i in 0..net.n_neurons() {
            let ci = self.core_of[i] as usize;
            for &t in net.neuron_targets(i) {
                s.total_synapses += 1;
                let ct = self.core_of[t as usize] as usize;
                let lvl = self.topology.level(ci, ct);
                if lvl > 0 {
                    s.cut_synapses += 1;
                    s.by_level[lvl as usize] += 1;
                }
            }
        }
        s
    }

    /// Invariants: every neuron on exactly one core, capacities met,
    /// members/local consistent.
    pub fn validate<'a>(&self, net: impl Into<NetView<'a>>, cap: CoreCapacity) -> Result<(), String> {
        let net: NetView<'_> = net.into();
        let n = net.n_neurons();
        if self.core_of.len() != n {
            return Err("core_of length mismatch".into());
        }
        let mut seen = vec![false; n];
        for (c, m) in self.members.iter().enumerate() {
            if m.len() > cap.max_neurons {
                return Err(format!("core {c} over neuron capacity"));
            }
            let syn: usize = m.iter().map(|&g| net.neuron_degree(g as usize)).sum();
            if syn > cap.max_synapses {
                return Err(format!("core {c} over synapse capacity"));
            }
            if m.windows(2).any(|w| w[0] >= w[1]) {
                return Err(format!("core {c} members not ascending"));
            }
            for (li, &g) in m.iter().enumerate() {
                if seen[g as usize] {
                    return Err(format!("neuron {g} on two cores"));
                }
                seen[g as usize] = true;
                if self.core_of[g as usize] as usize != c {
                    return Err(format!("neuron {g} core_of mismatch"));
                }
                if self.local_of[g as usize] as usize != li {
                    return Err(format!("neuron {g} local_of mismatch"));
                }
            }
        }
        if seen.iter().any(|&b| !b) {
            return Err("unassigned neuron".into());
        }
        Ok(())
    }
}

/// BFS over the synaptic graph from all axon roots (then any unreached
/// neurons in index order). Keeps synaptically-close neurons adjacent in
/// the seeding order.
fn bfs_order(net: NetView<'_>) -> Vec<u32> {
    let n = net.n_neurons();
    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);
    let mut queue = std::collections::VecDeque::new();
    for a in 0..net.n_axons() {
        for &t in net.axon_targets(a) {
            if !visited[t as usize] {
                visited[t as usize] = true;
                queue.push_back(t);
            }
        }
    }
    let mut cursor = 0usize;
    loop {
        while let Some(i) = queue.pop_front() {
            order.push(i);
            for &t in net.neuron_targets(i as usize) {
                if !visited[t as usize] {
                    visited[t as usize] = true;
                    queue.push_back(t);
                }
            }
        }
        while cursor < n && visited[cursor] {
            cursor += 1;
        }
        if cursor == n {
            break;
        }
        visited[cursor] = true;
        queue.push_back(cursor as u32);
    }
    order
}

/// Greedy gain-based migration: move a neuron to the core where it has the
/// most neighbours if that reduces cut and capacity allows. `passes`
/// bounds the sweeps (classic KL/FM simplification).
fn refine(
    net: NetView<'_>,
    core_of: &mut [u32],
    counts: &mut [(usize, usize)],
    cap: CoreCapacity,
    passes: usize,
) {
    let n = net.n_neurons();
    // build undirected neighbour lists (out + in)
    let mut neigh: Vec<Vec<u32>> = vec![Vec::new(); n];
    for i in 0..n {
        for &t in net.neuron_targets(i) {
            neigh[i].push(t);
            neigh[t as usize].push(i as u32);
        }
    }
    let n_cores = counts.len();
    let mut tally: Vec<u32> = vec![0; n_cores];
    for _ in 0..passes {
        let mut moved = 0usize;
        for i in 0..n {
            if neigh[i].is_empty() {
                continue;
            }
            // count neighbours per core (sparse tally with reset)
            let mut touched: Vec<u32> = Vec::with_capacity(neigh[i].len());
            for &t in &neigh[i] {
                let c = core_of[t as usize];
                if tally[c as usize] == 0 {
                    touched.push(c);
                }
                tally[c as usize] += 1;
            }
            let cur = core_of[i] as usize;
            let mut best = cur;
            let mut best_cnt = tally[cur];
            for &c in &touched {
                let c = c as usize;
                if tally[c] > best_cnt
                    && counts[c].0 + 1 <= cap.max_neurons
                    && counts[c].1 + net.neuron_degree(i) <= cap.max_synapses
                {
                    best = c;
                    best_cnt = tally[c];
                }
            }
            for &c in &touched {
                tally[c as usize] = 0;
            }
            if best != cur {
                counts[cur].0 -= 1;
                counts[cur].1 -= net.neuron_degree(i);
                counts[best].0 += 1;
                counts[best].1 += net.neuron_degree(i);
                core_of[i] = best as u32;
                moved += 1;
            }
        }
        if moved == 0 {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::snn::{Network, NetworkBuilder, NeuronModel, Synapse};
    use crate::util::prng::Xorshift32;
    use crate::util::ptest;

    fn clustered_net(rng: &mut Xorshift32, clusters: usize, per: usize) -> Network {
        // dense inside clusters, sparse across: refinement fodder
        let m = NeuronModel::if_neuron(10);
        let n = clusters * per;
        let mut b = NetworkBuilder::new();
        let keys: Vec<String> = (0..n).map(|i| format!("n{i}")).collect();
        for i in 0..n {
            let cl = i / per;
            let mut syns = Vec::new();
            for _ in 0..6 {
                let t = cl * per + rng.below(per as u32) as usize;
                syns.push((keys[t].clone(), 1i32));
            }
            if rng.chance(0.05) {
                syns.push((keys[rng.below(n as u32) as usize].clone(), 1));
            }
            let refs: Vec<(&str, i32)> = syns.iter().map(|(k, w)| (k.as_str(), *w)).collect();
            b.add_neuron(&keys[i], m, &refs).unwrap();
        }
        b.add_axon("in", &[("n0", 1)]).unwrap();
        b.build().unwrap().0
    }

    #[test]
    fn topology_levels() {
        let t = ClusterTopology { servers: 2, fpgas_per_server: 2, cores_per_fpga: 4 };
        assert_eq!(t.n_cores(), 16);
        assert_eq!(t.level(0, 0), 0);
        assert_eq!(t.level(0, 3), 1); // same fpga
        assert_eq!(t.level(0, 5), 2); // same server, other fpga
        assert_eq!(t.level(0, 9), 3); // other server
        assert_eq!(t.locate(9), (1, 0, 1));
    }

    #[test]
    fn single_core_trivial() {
        let mut rng = Xorshift32::new(1);
        let net = clustered_net(&mut rng, 2, 10);
        let p = Partition::compute(&net, ClusterTopology::single_core(), CoreCapacity::default())
            .unwrap();
        p.validate(&net, CoreCapacity::default()).unwrap();
        assert_eq!(p.n_used_cores(), 1);
        assert_eq!(p.cut_stats(&net).cut_synapses, 0);
    }

    #[test]
    fn capacity_forces_split() {
        let mut rng = Xorshift32::new(2);
        let net = clustered_net(&mut rng, 4, 25);
        let cap = CoreCapacity { max_neurons: 30, max_synapses: usize::MAX };
        let topo = ClusterTopology { servers: 1, fpgas_per_server: 1, cores_per_fpga: 8 };
        let p = Partition::compute(&net, topo, cap).unwrap();
        p.validate(&net, cap).unwrap();
        assert!(p.n_used_cores() >= 4);
    }

    #[test]
    fn refinement_beats_random_on_clustered_graph() {
        let mut rng = Xorshift32::new(3);
        let net = clustered_net(&mut rng, 4, 32);
        let cap = CoreCapacity { max_neurons: 40, max_synapses: usize::MAX };
        let topo = ClusterTopology { servers: 1, fpgas_per_server: 2, cores_per_fpga: 2 };
        let p = Partition::compute(&net, topo, cap).unwrap();
        p.validate(&net, cap).unwrap();
        let stats = p.cut_stats(&net);
        // random assignment would cut ~75%; locality + refinement must do
        // far better on a 4-cluster graph
        assert!(
            (stats.cut_synapses as f64) < 0.4 * stats.total_synapses as f64,
            "cut {} of {}",
            stats.cut_synapses,
            stats.total_synapses
        );
    }

    #[test]
    fn impossible_capacity_errors() {
        let mut rng = Xorshift32::new(4);
        let net = clustered_net(&mut rng, 2, 50);
        let cap = CoreCapacity { max_neurons: 10, max_synapses: usize::MAX };
        let topo = ClusterTopology::single_core();
        assert!(Partition::compute(&net, topo, cap).is_err());
    }

    #[test]
    fn prop_partition_invariants() {
        ptest::check("partition_invariants", 20, |rng| {
            let clusters = 1 + rng.below(4) as usize;
            let per = 8 + rng.below(24) as usize;
            let net = clustered_net(rng, clusters, per);
            let cap = CoreCapacity {
                max_neurons: per.max(8),
                max_synapses: usize::MAX,
            };
            let topo = ClusterTopology { servers: 2, fpgas_per_server: 2, cores_per_fpga: 8 };
            let p = Partition::compute(&net, topo, cap).map_err(|e| e)?;
            p.validate(&net, cap)?;
            // determinism
            let p2 = Partition::compute(&net, topo, cap).map_err(|e| e)?;
            ptest::prop_assert_eq(p.core_of.clone(), p2.core_of.clone(), "determinism")?;
            Ok(())
        });
    }

    #[test]
    fn bfs_order_reaches_all() {
        let m = NeuronModel::if_neuron(1);
        // disconnected graph, even with a cycle (3 <-> 4), no axons
        let mut neuron_adj: Vec<Vec<Synapse>> = vec![Vec::new(); 10];
        neuron_adj[3].push(Synapse { target: 4, weight: 1 });
        neuron_adj[4].push(Synapse { target: 3, weight: 1 });
        let net = Network::from_adj(vec![m; 10], &neuron_adj, &[], vec![], 0);
        let order = bfs_order(net.view());
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10u32).collect::<Vec<_>>());
    }
}
