"""Quantization + exact integer reference evaluation.

Quantization: per weighted layer, scale = HEADROOM / max|W| (dynamic
alpha scaling, paper §6), weights -> int16, biases -> int32, IF threshold
1.0 -> round(scale). Binary/IF neurons are scale-equivariant per layer,
so quantization only loses weight-rounding precision.

`int_forward_*` replicate the HiAER-Spike hardware update bit-exactly on
the layer graph (including the lam=63 "+1 per step on negative membrane"
floor-division quirk), so their accuracy is the paper's "software
accuracy after quantization", and must equal the Rust/hardware accuracy
exactly (Table 2's parity columns)."""

from __future__ import annotations

import numpy as np
import torch
import torch.nn as nn

HEADROOM = 8191.0  # keep |w_int| << 2^15 so sums stay far from i32 limits


def layer_scales(torch_layers, max_scale=HEADROOM):
    """Per weighted layer: quantization scale."""
    scales = []
    for m in torch_layers:
        if isinstance(m, (nn.Conv2d, nn.Linear)):
            wmax = float(m.weight.detach().abs().max())
            scales.append(max_scale / max(wmax, 1e-6))
    return scales


def quantized_arrays(torch_layers, scales):
    """Yield per-layer (kind, W_int float64, b_int float64|None, extras)."""
    out = []
    wi = 0
    for m in torch_layers:
        if isinstance(m, nn.Conv2d):
            s = scales[wi]
            w = np.clip(np.round(m.weight.detach().numpy().astype(np.float64) * s), -32768, 32767)
            b = (
                np.round(m.bias.detach().numpy().astype(np.float64) * s)
                if m.bias is not None
                else None
            )
            out.append(("conv", w, b, (m.stride[0], m.padding[0])))
            wi += 1
        elif isinstance(m, nn.Linear):
            s = scales[wi]
            w = np.clip(np.round(m.weight.detach().numpy().astype(np.float64) * s), -32768, 32767)
            b = (
                np.round(m.bias.detach().numpy().astype(np.float64) * s)
                if m.bias is not None
                else None
            )
            out.append(("fc", w, b, None))
            wi += 1
        elif isinstance(m, nn.MaxPool2d):
            k = m.kernel_size if isinstance(m.kernel_size, int) else m.kernel_size[0]
            st = m.stride if isinstance(m.stride, int) else m.stride[0]
            out.append(("pool", None, None, (k, st)))
    return out


def _conv_int(x, w, b, stride, pad):
    """Exact integer conv via float64 torch (values far below 2^52)."""
    xt = torch.from_numpy(x.astype(np.float64))
    wt = torch.from_numpy(w)
    bt = torch.from_numpy(b) if b is not None else None
    z = torch.nn.functional.conv2d(xt, wt, bt, stride=stride, padding=pad)
    return z.numpy()


def _fc_int(x, w, b):
    z = x.reshape(x.shape[0], -1).astype(np.float64) @ w.T
    if b is not None:
        z = z + b
    return z


def _pool_max(x, k, stride):
    xt = torch.from_numpy(x.astype(np.float64))
    return torch.nn.functional.max_pool2d(xt, k, stride).numpy()


def _pool_sum(x, k, stride):
    """Window sum (what the weight-1 pool neuron's membrane receives)."""
    xt = torch.from_numpy(x.astype(np.float64))
    return (torch.nn.functional.avg_pool2d(xt, k, stride) * (k * k)).round().numpy()


def int_forward_binary(qlayers, thetas, x):
    """ANN-binary cascade: spike = (z > theta). x: [B,C,H,W] binary.
    Returns final-layer membrane (logits) [B, n_out] int64."""
    act = x.astype(np.float64)
    wi = 0
    n = len(qlayers)
    for i, (kind, w, b, extra) in enumerate(qlayers):
        last = i == n - 1
        if kind == "conv":
            z = _conv_int(act, w, b, extra[0], extra[1])
            act = z if last else (z > thetas[wi]).astype(np.float64)
            wi += 1
        elif kind == "fc":
            z = _fc_int(act, w, b)
            act = z if last else (z > thetas[wi]).astype(np.float64)
            wi += 1
        else:
            act = _pool_max(act, extra[0], extra[1])
    return act.astype(np.int64)


def if_recurrence(z_train, theta):
    """HiAER IF recurrence over a per-step input train z_train
    [T_total, ...]: per step, spike (strict >), hard reset, lam=63 leak
    (v += 1 when v < 0: floor-division artifact), integrate.
    Returns the spike train [T_total, ...] and final membrane."""
    v = np.zeros_like(z_train[0])
    spikes = np.zeros_like(z_train)
    for t in range(len(z_train)):
        s = v > theta
        v = np.where(s, 0.0, v)
        v = v + (v < 0)  # v -= (v >> 31): +1 for negative v
        v = v + z_train[t]
        spikes[t] = s
    return spikes, v


def int_forward_if(qlayers, thetas, frames, extra_steps):
    """Rate-coded IF evaluation. frames: [B,T,C,H,W] binary. Runs
    T + extra_steps total steps (extra = #layers, the pipeline depth).
    Returns (spike counts [B,n_out], final membrane [B,n_out])."""
    b, t = frames.shape[0], frames.shape[1]
    t_total = t + extra_steps
    # layer-0 input train padded with empty frames
    train = np.zeros((t_total, b) + frames.shape[2:], np.float64)
    train[:t] = frames.transpose(1, 0, 2, 3, 4).astype(np.float64)
    wi = 0
    v = None
    for kind, w, bias, extra in qlayers:
        if kind == "conv":
            z = np.stack(
                [_conv_int(train[i], w, bias, extra[0], extra[1]) for i in range(t_total)]
            )
            train, v = if_recurrence(z, thetas[wi])
            wi += 1
        elif kind == "fc":
            z = np.stack([_fc_int(train[i], w, bias) for i in range(t_total)])
            train, v = if_recurrence(z, thetas[wi])
            wi += 1
        else:
            # pool neurons are IF with theta=0 fed weight-1 synapses: the
            # membrane receives the window SUM and fires (one step later)
            # iff it is > 0 — OR over binary inputs, like max pooling.
            z = np.stack([_pool_sum(train[i], extra[0], extra[1]) for i in range(t_total)])
            train, _ = if_recurrence(z, 0.0)
    counts = train.sum(axis=0)
    return counts.astype(np.int64), v.astype(np.int64)
