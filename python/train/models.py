"""Torch model definitions: binary (ANN) nets with straight-through
binary activations, and IF spiking nets with ATan surrogate gradients
matching HiAER-Spike's threshold/order-of-ops conventions (strict >,
integration at end of step, hard reset to 0)."""

from __future__ import annotations

import torch
import torch.nn as nn


class BinaryAct(torch.autograd.Function):
    """spike = (z > 0); STE gradient clipped to |z| < 1."""

    @staticmethod
    def forward(ctx, z):
        ctx.save_for_backward(z)
        return (z > 0).float()

    @staticmethod
    def backward(ctx, g):
        (z,) = ctx.saved_tensors
        return g * (z.abs() < 1.0).float()


class AtanSpike(torch.autograd.Function):
    """spike = (v > theta); ATan surrogate (SpikingJelly default)."""

    @staticmethod
    def forward(ctx, v):
        ctx.save_for_backward(v)
        return (v > 0).float()

    @staticmethod
    def backward(ctx, g):
        (v,) = ctx.saved_tensors
        alpha = 2.0
        return g * (alpha / 2) / (1 + (torch.pi / 2 * alpha * v) ** 2)


def binary(z):
    return BinaryAct.apply(z)


class BinaryNet(nn.Module):
    """A stack of conv/pool/fc layers with binary activations after every
    weighted layer — the ANN-neuron model family (binarized MNIST)."""

    def __init__(self, layers: list):
        super().__init__()
        self.layers = nn.ModuleList(layers)

    def forward(self, x):
        for m in self.layers:
            if isinstance(m, (nn.Conv2d, nn.Linear)):
                if isinstance(m, nn.Linear) and x.dim() > 2:
                    x = x.flatten(1)
                x = binary(m(x))
            else:  # pooling
                x = m(x)
        return x

    def logits(self, x):
        """Forward, but the LAST weighted layer returns raw z (the
        membrane potential the paper reads out instead of spikes)."""
        mods = list(self.layers)
        for i, m in enumerate(mods):
            last = i == len(mods) - 1
            if isinstance(m, (nn.Conv2d, nn.Linear)):
                if isinstance(m, nn.Linear) and x.dim() > 2:
                    x = x.flatten(1)
                z = m(x)
                x = z if last else binary(z)
            else:
                x = m(x)
        return x


class IFNet(nn.Module):
    """Rate-coded IF spiking net matching HiAER-Spike semantics: per step,
    threshold (strict >) then hard reset then integrate; threshold 1.0
    during training (rescaled at quantization). Input: [B, T, C, H, W]."""

    def __init__(self, layers: list):
        super().__init__()
        self.layers = nn.ModuleList(layers)

    def forward(self, x):
        """Returns output spike-count rates [B, n_out]."""
        b, t = x.shape[0], x.shape[1]
        # per-layer membrane states
        vs = [None] * len(self.layers)
        counts = None
        for step in range(t):
            cur = x[:, step]
            for i, m in enumerate(self.layers):
                if isinstance(m, (nn.Conv2d, nn.Linear)):
                    if isinstance(m, nn.Linear) and cur.dim() > 2:
                        cur = cur.flatten(1)
                    z = m(cur)
                    if vs[i] is None:
                        vs[i] = torch.zeros_like(z)
                    # integrate this step's input, then spike at the next
                    # threshold crossing — equivalent rate semantics to the
                    # hardware's (threshold -> reset -> integrate) order.
                    v = vs[i] + z
                    s = AtanSpike.apply(v - 1.0)
                    vs[i] = v * (1 - s.detach())  # hard reset to 0
                    cur = s
                else:
                    cur = m(cur)
            counts = cur if counts is None else counts + cur
        return counts / t
