"""Binary exporters: `.hsl` layer graphs (read by rust/src/model_fmt/hsl.rs)
and `.hsd` test sets (read by rust/src/model_fmt/testset.rs)."""

from __future__ import annotations

import struct

import numpy as np
import torch.nn as nn

HSL_MAGIC = b"HSLAY1\x00\x00"
HSD_MAGIC = b"HSDATA1\x00"


def write_hsl(
    path: str,
    torch_layers,
    scales,
    thetas,
    neuron_kind: int,
    in_shape,
    timesteps: int,
):
    """Serialise quantized torch layers.

    torch_layers: the module list (Conv2d / Linear / MaxPool2d);
    scales: per-weighted-layer quantization scale (weights multiplied then
    rounded); thetas: per-weighted-layer int threshold.
    """
    c, h, w = in_shape
    out = bytearray()
    out += HSL_MAGIC
    out += struct.pack("<I", 1)
    out += struct.pack("<B", neuron_kind)
    out += struct.pack("<IIIII", c, h, w, timesteps, len(list(torch_layers)))
    wi = 0
    for m in torch_layers:
        if isinstance(m, nn.Conv2d):
            s = scales[wi]
            wq = np.clip(np.round(m.weight.detach().numpy() * s), -32768, 32767).astype("<i2")
            out += struct.pack("<B", 0)
            out += struct.pack(
                "<IIIII",
                m.out_channels,
                m.kernel_size[0],
                m.kernel_size[1],
                m.stride[0],
                m.padding[0],
            )
            out += struct.pack("<i", int(thetas[wi]))
            has_bias = m.bias is not None
            out += struct.pack("<B", int(has_bias))
            out += wq.tobytes()
            if has_bias:
                bq = np.round(m.bias.detach().numpy() * s).astype("<i4")
                out += bq.tobytes()
            wi += 1
        elif isinstance(m, nn.Linear):
            s = scales[wi]
            wq = np.clip(np.round(m.weight.detach().numpy() * s), -32768, 32767).astype("<i2")
            out += struct.pack("<B", 1)
            out += struct.pack("<I", m.out_features)
            out += struct.pack("<i", int(thetas[wi]))
            has_bias = m.bias is not None
            out += struct.pack("<B", int(has_bias))
            out += wq.tobytes()  # [out, in] row-major
            if has_bias:
                bq = np.round(m.bias.detach().numpy() * s).astype("<i4")
                out += bq.tobytes()
            wi += 1
        elif isinstance(m, nn.MaxPool2d):
            out += struct.pack("<B", 2)
            k = m.kernel_size if isinstance(m.kernel_size, int) else m.kernel_size[0]
            st = m.stride if isinstance(m.stride, int) else m.stride[0]
            out += struct.pack("<II", k, st)
        else:
            raise TypeError(f"unsupported layer {m}")
    with open(path, "wb") as f:
        f.write(bytes(out))


def write_hsd(path: str, samples, labels, n_axons: int):
    """Test set: samples is a list of per-sample frame lists; each frame is
    a sorted array of active axon ids. labels: int array."""
    frames_per_sample = len(samples[0])
    out = bytearray()
    out += HSD_MAGIC
    out += struct.pack("<III", len(samples), frames_per_sample, n_axons)
    for frames, label in zip(samples, labels):
        assert len(frames) == frames_per_sample
        out += struct.pack("<B", int(label))
        for fr in frames:
            ids = np.asarray(fr, "<u4")
            out += struct.pack("<I", len(ids))
            out += ids.tobytes()
    with open(path, "wb") as f:
        f.write(bytes(out))


def frames_from_binary(x: np.ndarray) -> list:
    """[C,H,W] or [T,C,H,W] binary array -> list of per-frame active axon
    id arrays (axon id = c*H*W + y*W + x, matching convert/mod.rs)."""
    if x.ndim == 3:
        x = x[None]
    t = x.shape[0]
    flat = x.reshape(t, -1)
    return [np.nonzero(flat[i])[0].astype(np.uint32) for i in range(t)]
