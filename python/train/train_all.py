"""Train every Table-2 model family and export .hsl/.hsd + manifest.

Usage:  cd python && python -m train.train_all [--out ../models] [--quick]

Architectures follow the paper's families, channel-scaled to train in
minutes on CPU (the paper's absolute accuracy is not the reproduction
target — software<->hardware parity and energy/latency scaling are).
IF (spiking) nets are trained without biases: the paper's conversion
absorbs/drops them, and bias-free layers make threshold-mode conversion
exact for rate coding.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np
import torch
import torch.nn as nn

from data import dvs_gesture, pong, synth_cifar, synth_mnist
from train import export, qat
from train.models import BinaryNet, IFNet

torch.manual_seed(0)


def train_torch(model, xs, ys, *, epochs, batch, lr=1e-3, spiking=False):
    opt = torch.optim.Adam(model.parameters(), lr=lr)
    loss_fn = nn.CrossEntropyLoss()
    n = len(xs)
    for ep in range(epochs):
        perm = torch.randperm(n)
        tot = 0.0
        for i in range(0, n, batch):
            idx = perm[i : i + batch]
            x = torch.from_numpy(xs[idx.numpy()]).float()
            y = torch.from_numpy(ys[idx.numpy()])
            opt.zero_grad()
            out = model(x) if spiking else model.logits(x)
            loss = loss_fn(out, y)
            loss.backward()
            opt.step()
            tot += float(loss) * len(idx)
        print(f"    epoch {ep + 1}/{epochs} loss {tot / n:.4f}", flush=True)


def eval_float(model, xs, ys, batch=128, spiking=False):
    correct = 0
    with torch.no_grad():
        for i in range(0, len(xs), batch):
            x = torch.from_numpy(xs[i : i + batch]).float()
            out = model(x) if spiking else model.logits(x)
            correct += int((out.argmax(1).numpy() == ys[i : i + batch]).sum())
    return correct / len(xs)


def eval_quant_binary(layers, thetas_int, xs, ys, batch=256):
    q = qat.quantized_arrays(layers, qat.layer_scales(layers))
    correct = 0
    for i in range(0, len(xs), batch):
        logits = qat.int_forward_binary(q, thetas_int, xs[i : i + batch])
        correct += int((logits.argmax(1) == ys[i : i + batch]).sum())
    return correct / len(xs)


def eval_quant_if(layers, scales, xs, ys, batch=16):
    q = qat.quantized_arrays(layers, scales)
    thetas = [round(s) for s in scales]
    n_weighted = len(thetas)
    n_layers = len(list(layers))
    correct = 0
    for i in range(0, len(xs), batch):
        counts, v = qat.int_forward_if(q, thetas, xs[i : i + batch], n_layers)
        # rate readout with membrane tie-break
        pred = (counts * 1_000_000 + np.clip(v, -500_000, 500_000)).argmax(1)
        correct += int((pred == ys[i : i + batch]).sum())
    del q
    return correct / len(xs), thetas, n_weighted


def export_model(out_dir, name, layers, thetas, kind, in_shape, timesteps, scales):
    path = os.path.join(out_dir, f"{name}.hsl")
    export.write_hsl(path, layers, scales, thetas, kind, in_shape, timesteps)
    return path


def count_params(layers):
    return sum(
        int(np.prod(m.weight.shape)) for m in layers if isinstance(m, (nn.Conv2d, nn.Linear))
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "..", "models"))
    ap.add_argument("--quick", action="store_true", help="tiny datasets, 1 epoch (CI)")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    quick = args.quick
    manifest = {}

    n_train = 400 if quick else 2500
    n_test = 100 if quick else 500
    epochs = 1 if quick else 6

    # ------------------------------------------------------------- MNIST
    print("== synthetic MNIST (binary ANN nets)")
    xs, ys = synth_mnist.generate(n_train, seed=1)
    xt, yt = synth_mnist.generate(n_test, seed=2)
    xs4 = xs[:, None].astype(np.float32)
    xt4 = xt[:, None].astype(np.float32)

    mnist_models = {
        "mlp_128": [nn.Linear(784, 128), nn.Linear(128, 10)],
        "mlp_2k1k": [nn.Linear(784, 2048), nn.Linear(2048, 1024), nn.Linear(1024, 10)],
        "lenet5_s2": [
            nn.Conv2d(1, 6, 5, stride=2),
            nn.Conv2d(6, 16, 5, stride=2),
            nn.Linear(16 * 4 * 4, 120),
            nn.Linear(120, 84),
            nn.Linear(84, 10),
        ],
        "lenet5_mp": [
            nn.Conv2d(1, 6, 5),
            nn.MaxPool2d(2, 2),
            nn.Conv2d(6, 16, 5),
            nn.MaxPool2d(2, 2),
            nn.Linear(16 * 4 * 4, 120),
            nn.Linear(120, 84),
            nn.Linear(84, 10),
        ],
    }
    for name, layers in mnist_models.items():
        print(f"  -- {name}")
        model = BinaryNet(layers)
        t0 = time.time()
        train_torch(model, xs4.reshape(len(xs4), 1, 28, 28), ys, epochs=epochs, batch=64)
        acc_f = eval_float(model, xt4, yt)
        thetas = [0] * sum(isinstance(m, (nn.Conv2d, nn.Linear)) for m in layers)
        acc_q = eval_quant_binary(model.layers, thetas, xt4, yt)
        scales = qat.layer_scales(model.layers)
        export_model(out_dir, name, model.layers, thetas, 0, (1, 28, 28), 1, scales)
        export.write_hsd(
            os.path.join(out_dir, f"{name}.hsd"),
            [export.frames_from_binary(x) for x in xt4.astype(np.uint8)],
            yt,
            784,
        )
        manifest[name] = {
            "task": "mnist",
            "kind": "ann",
            "readout": "membrane",
            "input": [1, 28, 28],
            "timesteps": 1,
            "acc_float": acc_f,
            "acc_quant": acc_q,
            "params": count_params(layers),
            "train_s": round(time.time() - t0, 1),
        }
        print(f"    float {acc_f:.4f} quant {acc_q:.4f}")

    # -------------------------------------------------------- DVS gesture
    print("== synthetic DVS gesture (IF spiking CNN family)")
    n_train_g = 200 if quick else 700
    n_test_g = 60 if quick else 200
    gx, gy = dvs_gesture.generate(n_train_g, seed=3)
    gxt, gyt = dvs_gesture.generate(n_test_g, seed=4)
    gx = gx.astype(np.float32)
    gxt = gxt.astype(np.float32)

    def dvs_fc_in(conv_specs, size=63):
        c, h, w = 2, size, size
        for out_c, k, s in conv_specs:
            h = (h - k) // s + 1
            w = (w - k) // s + 1
            c = out_c
        return c * h * w

    gesture_family = {
        "dvs_c4": [(4, 5, 2)],
        "dvs_c8": [(8, 5, 2)],
        "dvs_c8c8": [(8, 5, 2), (8, 5, 2)],
        "dvs_c12c16": [(12, 5, 2), (16, 5, 2)],
        "dvs_c16c24": [(16, 5, 2), (24, 5, 2)],
    }
    ge = 1 if quick else 3
    for name, spec in gesture_family.items():
        print(f"  -- {name}")
        layers = []
        in_c = 2
        for out_c, k, s in spec:
            layers.append(nn.Conv2d(in_c, out_c, k, stride=s, bias=False))
            in_c = out_c
        layers += [
            nn.Linear(dvs_fc_in(spec), 120, bias=False),
            nn.Linear(120, 84, bias=False),
            nn.Linear(84, 11, bias=False),
        ]
        model = IFNet(layers)
        t0 = time.time()
        train_torch(model, gx, gy, epochs=ge, batch=16, spiking=True)
        acc_f = eval_float(model, gxt, gyt, batch=16, spiking=True)
        scales = qat.layer_scales(model.layers)
        acc_q, thetas, _ = eval_quant_if(model.layers, scales, gxt, gyt)
        export_model(out_dir, name, model.layers, thetas, 1, (2, 63, 63), 10, scales)
        export.write_hsd(
            os.path.join(out_dir, f"{name}.hsd"),
            [export.frames_from_binary(x) for x in gxt.astype(np.uint8)],
            gyt,
            2 * 63 * 63,
        )
        manifest[name] = {
            "task": "dvs_gesture",
            "kind": "if",
            "readout": "rate",
            "input": [2, 63, 63],
            "timesteps": 10,
            "acc_float": acc_f,
            "acc_quant": acc_q,
            "params": count_params(layers),
            "train_s": round(time.time() - t0, 1),
        }
        print(f"    float {acc_f:.4f} quant {acc_q:.4f}")

    # ----------------------------------------------------------- CIFAR-10
    print("== synthetic CIFAR-10 (bit-sliced, IF CNN)")
    cx, cy = synth_cifar.generate(n_train, seed=5)
    cxt, cyt = synth_cifar.generate(n_test, seed=6)
    # present the 15-plane image at every one of T timesteps (rate code)
    T_CIFAR = 4
    cx_t = np.repeat(cx[:, None], T_CIFAR, axis=1).astype(np.float32)
    cxt_t = np.repeat(cxt[:, None], T_CIFAR, axis=1).astype(np.float32)
    layers = [
        nn.Conv2d(15, 16, 3, stride=2, bias=False),
        nn.Conv2d(16, 32, 3, stride=2, bias=False),
        nn.Linear(32 * 7 * 7, 256, bias=False),
        nn.Linear(256, 10, bias=False),
    ]
    model = IFNet(layers)
    t0 = time.time()
    train_torch(model, cx_t, cy, epochs=max(1, epochs // 2), batch=32, spiking=True)
    acc_f = eval_float(model, cxt_t, cyt, batch=32, spiking=True)
    scales = qat.layer_scales(model.layers)
    acc_q, thetas, _ = eval_quant_if(model.layers, scales, cxt_t, cyt)
    export_model(out_dir, "cifar_cnn", model.layers, thetas, 1, (15, 32, 32), T_CIFAR, scales)
    export.write_hsd(
        os.path.join(out_dir, "cifar_cnn.hsd"),
        [[f[0]] * T_CIFAR for f in ([export.frames_from_binary(x) for x in cxt.astype(np.uint8)])],
        cyt,
        15 * 32 * 32,
    )
    manifest["cifar_cnn"] = {
        "task": "cifar10",
        "kind": "if",
        "readout": "rate",
        "input": [15, 32, 32],
        "timesteps": T_CIFAR,
        "acc_float": acc_f,
        "acc_quant": acc_q,
        "params": count_params(layers),
        "train_s": round(time.time() - t0, 1),
    }
    print(f"    float {acc_f:.4f} quant {acc_q:.4f}")

    # --------------------------------------------------------------- Pong
    print("== DVS Pong (behaviour cloning of the scripted expert)")
    n_bc = 1500 if quick else 8000
    px, pa = pong.collect_bc_dataset(n_bc, seed=7)
    T_PONG = 4
    px_t = np.repeat(px[:, None], T_PONG, axis=1).astype(np.float32)
    layers = [
        nn.Conv2d(2, 8, 8, stride=4, bias=False),
        nn.Conv2d(8, 16, 4, stride=2, bias=False),
        nn.Linear(16 * 9 * 9, 128, bias=False),
        nn.Linear(128, 6, bias=False),
    ]
    model = IFNet(layers)
    t0 = time.time()
    train_torch(model, px_t, pa, epochs=max(1, epochs // 3), batch=32, spiking=True)
    acc_f = eval_float(model, px_t[: len(px_t) // 4], pa[: len(pa) // 4], batch=32, spiking=True)
    scales = qat.layer_scales(model.layers)
    acc_q, thetas, _ = eval_quant_if(
        model.layers, scales, px_t[: len(px_t) // 8], pa[: len(pa) // 8]
    )
    export_model(out_dir, "pong_dqn", model.layers, thetas, 1, (2, 84, 84), T_PONG, scales)
    manifest["pong_dqn"] = {
        "task": "pong",
        "kind": "if",
        "readout": "rate",
        "input": [2, 84, 84],
        "timesteps": T_PONG,
        "acc_float": acc_f,  # action agreement with the expert
        "acc_quant": acc_q,
        "params": count_params(layers),
        "train_s": round(time.time() - t0, 1),
    }
    print(f"    action-agreement float {acc_f:.4f} quant {acc_q:.4f}")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest -> {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
