"""Quantization-aware training pipeline (build-time only).

Trains the paper's Table-2 model families on the synthetic datasets,
quantizes weights to int16, exports `.hsl` layer graphs + `.hsd` test
sets for the Rust platform, and records fp32/quantized software
accuracies in `models/manifest.json`.
"""
