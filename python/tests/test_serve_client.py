"""Client-side tests for the shared-server (TCP) path: the new stable
error codes (``quota`` / ``server_busy`` / ``deadline`` / ``evicted``),
``health``/``metrics`` marshalling, typed rejection when the server
answers ``server_busy`` instead of ``hello``, TcpTransport's bounded
connect retry, and the context-manager close contract.

Wire-level behaviour (eviction, fair queueing, drain) lives in
``rust/tests/serve_tcp.rs``; here we pin the Python half against fakes
plus a tiny in-thread scripted TCP server — no Rust binary required."""

import json
import socket
import threading

import pytest

from hs_api import (
    HsBackendUnavailable,
    HsProtocolError,
    HsQuotaError,
    HsServerBusy,
    HsSessionError,
    SessionClient,
    TcpTransport,
)
from hs_api.backend import RustSessionBackend
from hs_api.session import _parse_address

HELLO = {"ok": True, "op": "hello", "protocol": 1, "backend": "rust"}


class FakeTransport:
    """Scripted transport: canned response lines, recorded sends."""

    def __init__(self, responses, hello=True):
        self.responses = ([json.dumps(HELLO)] if hello else []) + list(responses)
        self.sent = []
        self.closed = False

    def send_line(self, line):
        self.sent.append(line)

    def recv_line(self):
        if not self.responses:
            raise HsProtocolError("server closed the connection", code="closed")
        return self.responses.pop(0)

    def close(self):
        self.closed = True


def client_with(*responses):
    return SessionClient(FakeTransport([json.dumps(r) for r in responses]))


# ------------------------------------------------- new codes -> exceptions


@pytest.mark.parametrize(
    "code,exc",
    [
        ("quota", HsQuotaError),
        ("server_busy", HsServerBusy),
        ("deadline", HsServerBusy),
        ("evicted", HsSessionError),
    ],
)
def test_serving_tier_codes_map_to_typed_exceptions(code, exc):
    c = client_with({"ok": False, "code": code, "error": f"boom ({code})"})
    with pytest.raises(exc) as ei:
        c.step([0])
    assert ei.value.code == code
    assert code in str(ei.value)


def test_server_busy_instead_of_hello_raises_typed_error():
    busy = {"ok": False, "code": "server_busy",
            "error": "server at max_sessions capacity; retry later"}
    with pytest.raises(HsServerBusy) as ei:
        SessionClient(FakeTransport([json.dumps(busy)], hello=False))
    assert ei.value.code == "server_busy"
    assert "capacity" in str(ei.value)


# -------------------------------------------------------- health / metrics


def test_health_marshalling_strips_envelope():
    c = client_with({"ok": True, "op": "health", "sessions": 2, "max_sessions": 32,
                     "queue_depth": 0, "draining": False, "uptime_ms": 1234})
    h = c.health()
    assert h == {"sessions": 2, "max_sessions": 32, "queue_depth": 0,
                 "draining": False, "uptime_ms": 1234}
    assert json.loads(c.transport.sent[0]) == {"op": "health"}


def test_metrics_marshalling_strips_envelope():
    c = client_with({"ok": True, "op": "metrics", "requests_total": 9,
                     "errors_total": 1, "steps_total": 40, "evicted_panic": 0,
                     "steps_per_s": 123.5})
    m = c.metrics()
    assert m["steps_total"] == 40
    assert m["steps_per_s"] == 123.5
    assert "ok" not in m and "op" not in m
    assert json.loads(c.transport.sent[0]) == {"op": "metrics"}


# ------------------------------------------------------- context manager


def test_context_manager_always_closes_and_tries_shutdown():
    t = FakeTransport([json.dumps({"ok": True, "op": "shutdown"})])
    with SessionClient(t) as c:
        assert c.server_backend == "rust"
    assert t.closed
    assert json.loads(t.sent[-1]) == {"op": "shutdown"}


def test_context_manager_close_survives_dead_server():
    class DeadSendTransport(FakeTransport):
        def send_line(self, line):
            raise HsProtocolError("server pipe closed", code="closed")

    t = DeadSendTransport([])
    with SessionClient(t):
        pass  # close() must swallow the failed best-effort shutdown
    assert t.closed


# ------------------------------------------------------------ TcpTransport


def test_parse_address_forms():
    assert _parse_address("127.0.0.1:9000") == ("127.0.0.1", 9000)
    assert _parse_address("[::1]:9000") == ("::1", 9000)
    assert _parse_address(("10.0.0.2", 7777)) == ("10.0.0.2", 7777)
    with pytest.raises(ValueError, match="host:port"):
        _parse_address("no-port-here")
    with pytest.raises(ValueError, match="host:port"):
        _parse_address("host:notaport")


def test_tcp_connect_retries_are_bounded_and_typed(monkeypatch):
    attempts = []

    def refused(addr, timeout=None):
        attempts.append(addr)
        raise ConnectionRefusedError("nobody listening")

    monkeypatch.setattr(socket, "create_connection", refused)
    with pytest.raises(HsBackendUnavailable) as ei:
        TcpTransport("127.0.0.1:1", connect_retries=3, retry_backoff_s=0.001)
    assert len(attempts) == 3
    assert "after 3 attempt(s)" in str(ei.value)
    assert ei.value.code == "backend_unavailable"


class LineServer(threading.Thread):
    """One-connection scripted JSON-lines server on an ephemeral port:
    greets with hello, answers each op with a canned response, records
    everything it saw."""

    def __init__(self):
        super().__init__(daemon=True)
        self.sock = socket.socket()
        self.sock.bind(("127.0.0.1", 0))
        self.sock.listen(1)
        self.addr = "127.0.0.1:%d" % self.sock.getsockname()[1]
        self.seen = []

    def run(self):
        conn, _ = self.sock.accept()
        f = conn.makefile("rw", encoding="utf-8", newline="\n")
        f.write(json.dumps(HELLO) + "\n")
        f.flush()
        for line in f:
            req = json.loads(line)
            self.seen.append(req)
            op = req.get("op")
            if op == "step":
                resp = {"ok": True, "op": "step", "spikes": [1], "fired": 1}
            elif op == "health":
                resp = {"ok": True, "op": "health", "sessions": 1,
                        "queue_depth": 0, "draining": False}
            else:
                resp = {"ok": True, "op": op}
            f.write(json.dumps(resp) + "\n")
            f.flush()
            if op == "shutdown":
                break
        conn.close()
        self.sock.close()


def test_tcp_transport_speaks_the_protocol_end_to_end():
    server = LineServer()
    server.start()
    with SessionClient(TcpTransport(server.addr, timeout_s=10.0)) as c:
        assert c.server_backend == "rust"
        assert c.step([0]) == [1]
        assert c.health()["draining"] is False
    server.join(timeout=10)
    assert not server.is_alive(), "server thread must see the shutdown and exit"
    ops = [r["op"] for r in server.seen]
    assert ops == ["step", "health", "shutdown"], (
        "context-manager exit sends a best-effort shutdown"
    )


def test_tcp_transport_retry_then_success(monkeypatch):
    server = LineServer()
    server.start()
    real = socket.create_connection
    attempts = []

    def flaky(addr, timeout=None):
        attempts.append(addr)
        if len(attempts) < 3:
            raise ConnectionRefusedError("still booting")
        return real(addr, timeout=timeout)

    monkeypatch.setattr(socket, "create_connection", flaky)
    with SessionClient(
        TcpTransport(server.addr, connect_retries=5, retry_backoff_s=0.001,
                     timeout_s=10.0)
    ) as c:
        assert c.step([0]) == [1]
    assert len(attempts) == 3, "connect succeeds on the first good attempt"
    server.join(timeout=10)


# ------------------------------------------------------- backend address=


def test_rust_backend_address_uses_tcp_transport(monkeypatch):
    import hs_api.backend as backend_mod

    made = []

    def fake_tcp(address):
        made.append(address)
        return FakeTransport([])

    monkeypatch.setattr(backend_mod, "TcpTransport", fake_tcp)
    b = RustSessionBackend(address="10.1.2.3:9000")
    client = b._launch()
    assert isinstance(client, SessionClient)
    assert made == ["10.1.2.3:9000"]


def test_rust_backend_address_busy_greeting_closes_socket(monkeypatch):
    import hs_api.backend as backend_mod

    busy = {"ok": False, "code": "server_busy", "error": "draining"}
    t = FakeTransport([json.dumps(busy)], hello=False)
    monkeypatch.setattr(backend_mod, "TcpTransport", lambda address: t)
    b = RustSessionBackend(address="10.1.2.3:9000")
    with pytest.raises(HsServerBusy):
        b._launch()
    assert t.closed, "a refused greeting must not leak the socket"
