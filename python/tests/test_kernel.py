"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes/params; every case asserts bit-exact equality
(int32 semantics, so allclose == equality)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import neuron_update, ref


def run_both(v, theta, nu, lam, flags, seed, block=256):
    ss = jnp.uint32(seed)
    v1, s1 = ref.neuron_update_ref(v, theta, nu, lam, flags, ss)
    v2, s2 = neuron_update(
        jnp.asarray(v), jnp.asarray(theta), jnp.asarray(nu),
        jnp.asarray(lam), jnp.asarray(flags), ss, block=block,
    )
    return (np.asarray(v1), np.asarray(s1)), (np.asarray(v2), np.asarray(s2))


def rand_case(rng, n):
    return (
        rng.randint(-(2**24), 2**24, n).astype(np.int32),
        rng.randint(-(2**15), 2**16, n).astype(np.int32),
        rng.randint(-32, 32, n).astype(np.int32),
        rng.randint(0, 64, n).astype(np.int32),
        rng.randint(0, 4, n).astype(np.int32),
    )


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 8),
    seed=st.integers(0, 2**32 - 1),
    data_seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_random(n_blocks, seed, data_seed):
    rng = np.random.RandomState(data_seed)
    n = 256 * n_blocks
    v, theta, nu, lam, flags = rand_case(rng, n)
    (v1, s1), (v2, s2) = run_both(v, theta, nu, lam, flags, seed)
    np.testing.assert_array_equal(v1, v2)
    np.testing.assert_array_equal(s1, s2)


@settings(max_examples=10, deadline=None)
@given(block_pow=st.sampled_from([128, 256, 512, 1024]), seed=st.integers(0, 2**32 - 1))
def test_block_size_equivalence(block_pow, seed):
    """Result must not depend on the VMEM tile size (pure data parallel)."""
    rng = np.random.RandomState(7)
    n = 2048
    v, theta, nu, lam, flags = rand_case(rng, n)
    (_, _), (v_a, s_a) = run_both(v, theta, nu, lam, flags, seed, block=block_pow)
    (_, _), (v_b, s_b) = run_both(v, theta, nu, lam, flags, seed, block=256)
    np.testing.assert_array_equal(v_a, v_b)
    np.testing.assert_array_equal(s_a, s_b)


def test_strict_threshold():
    """V == theta must NOT spike (paper: strict >, unlike SpikingJelly >=)."""
    n = 256
    v = np.full(n, 100, np.int32)
    theta = np.full(n, 100, np.int32)
    flags = np.zeros(n, np.int32)  # ANN, deterministic
    (_, s1), (_, s2) = run_both(v, theta, np.zeros(n, np.int32),
                                np.zeros(n, np.int32), flags, 1)
    assert s1.sum() == 0 and s2.sum() == 0
    v2 = v + 1
    (_, s1), (_, s2) = run_both(v2, theta, np.zeros(n, np.int32),
                                np.zeros(n, np.int32), flags, 1)
    assert s1.sum() == n and s2.sum() == n


def test_ann_clears_membrane():
    """ANN neurons accumulate no membrane potential between steps."""
    n = 256
    v = np.arange(-128, 128, dtype=np.int32)
    theta = np.full(n, 2**30, np.int32)  # never spike
    flags = np.zeros(n, np.int32)
    (v1, _), (v2, _) = run_both(v, theta, np.zeros(n, np.int32),
                                np.zeros(n, np.int32), flags, 1)
    assert (v1 == 0).all() and (v2 == 0).all()


@pytest.mark.parametrize("lam,expect", [
    (0, 0),        # v - (v >> 0) = 0
    (1, 500),      # 1000 - 500
    (2, 750),      # 1000 - 250
    (63, 1000),    # clamped shift 31 -> v - 0
])
def test_lif_leak_values(lam, expect):
    n = 256
    v = np.full(n, 1000, np.int32)
    theta = np.full(n, 2**30, np.int32)
    flags = np.full(n, ref.FLAG_LIF, np.int32)
    (v1, _), (v2, _) = run_both(v, theta, np.zeros(n, np.int32),
                                np.full(n, lam, np.int32), flags, 1)
    assert (v1 == expect).all() and (v2 == expect).all()


def test_lif_leak_negative_floor():
    """Leak uses floor division (python //): -1000 - (-1000 >> 2) = -750."""
    n = 256
    v = np.full(n, -1000, np.int32)
    theta = np.full(n, 2**30, np.int32)
    flags = np.full(n, ref.FLAG_LIF, np.int32)
    (v1, _), (v2, _) = run_both(v, theta, np.zeros(n, np.int32),
                                np.full(n, 2, np.int32), flags, 1)
    # -1000 >> 2 == floor(-1000/4) == -250; v - (-250) == -750
    assert (v1 == -750).all() and (v2 == -750).all()


def test_noise_is_odd_and_bounded():
    """Raw 17-bit noise: odd, in [-2^16, 2^16), and roughly balanced."""
    idx = np.arange(65536, dtype=np.uint32)
    xi = np.asarray(ref.noise17(jnp.uint32(99), idx))
    assert (xi % 2 != 0).all()
    assert xi.min() >= -(2**16) and xi.max() < 2**16
    # LSB=1 balances the distribution around 0 (paper 5.1)
    assert abs(float(xi.mean())) < 300.0


def test_noise_shift_left_right():
    xi = np.asarray(ref.noise17(jnp.uint32(5), np.arange(256, dtype=np.uint32)))
    left = np.asarray(ref.shift_noise(jnp.asarray(xi), jnp.full(256, 3, jnp.int32)))
    right = np.asarray(ref.shift_noise(jnp.asarray(xi), jnp.full(256, -3, jnp.int32)))
    np.testing.assert_array_equal(left, (xi.astype(np.int64) << 3).astype(np.int32))
    np.testing.assert_array_equal(right, xi >> 3)


def test_deterministic_neurons_see_no_noise():
    n = 256
    v = np.full(n, 10, np.int32)
    theta = np.full(n, 2**30, np.int32)
    flags = np.full(n, ref.FLAG_LIF, np.int32)  # no FLAG_NOISE
    lam = np.full(n, 63, np.int32)
    (v1, _), (v2, _) = run_both(v, theta, np.full(n, 5, np.int32), lam, flags, 1234)
    assert (v1 == 10).all() and (v2 == 10).all()


def test_stochastic_binary_is_boltzmann_like():
    """ANN neuron with noise and theta=0 fires ~50% of the time (nu=-17
    keeps |xi| small but sign-balanced)."""
    n = 65536
    v = np.zeros(n, np.int32)
    theta = np.zeros(n, np.int32)
    flags = np.full(n, ref.FLAG_NOISE, np.int32)
    nu = np.zeros(n, np.int32)
    (_, s1), _ = run_both(v, theta, nu, np.zeros(n, np.int32), flags, 31337)
    rate = s1.mean()
    assert 0.45 < rate < 0.55


def test_mix_seed_varies_per_step():
    seeds = {int(ref.mix_seed(1, t)) for t in range(100)}
    assert len(seeds) == 100
    assert all(s != 0 for s in seeds)
