"""L2 graph tests: synapse_accum scatter semantics, dense_step equivalence,
and lowering shape checks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(data_seed=st.integers(0, 2**31 - 1))
def test_synapse_accum_drops_padding(data_seed):
    rng = np.random.RandomState(data_seed)
    n, e = 512, 1024
    v = rng.randint(-1000, 1000, n).astype(np.int32)
    targets = rng.randint(0, n + 1, e).astype(np.int32)
    weights = rng.randint(-100, 100, e).astype(np.int32)
    got = np.asarray(model.synapse_accum_fn(jnp.asarray(v), jnp.asarray(targets),
                                            jnp.asarray(weights)))
    want = v.copy().astype(np.int64)
    for t, w in zip(targets, weights):
        if t < n:
            want[t] += w
    np.testing.assert_array_equal(got, want.astype(np.int32))


@settings(max_examples=10, deadline=None)
@given(data_seed=st.integers(0, 2**31 - 1), seed=st.integers(0, 2**32 - 1))
def test_dense_step_matches_ref(data_seed, seed):
    rng = np.random.RandomState(data_seed)
    n, a = 256, 64
    v = rng.randint(-500, 500, n).astype(np.int32)
    theta = rng.randint(0, 200, n).astype(np.int32)
    nu = rng.randint(-20, 10, n).astype(np.int32)
    lam = rng.randint(0, 64, n).astype(np.int32)
    flags = rng.randint(0, 4, n).astype(np.int32)
    wn = rng.randint(-30, 30, (n, n)).astype(np.int32)
    wa = rng.randint(-30, 30, (a, n)).astype(np.int32)
    ax = (rng.rand(a) < 0.4).astype(np.int32)
    ss = jnp.uint32(seed)
    v1, s1 = ref.dense_step_ref(v, theta, nu, lam, flags, ss, wn, wa, ax)
    v2, s2 = model.dense_step_fn(
        jnp.asarray(v), jnp.asarray(theta), jnp.asarray(nu), jnp.asarray(lam),
        jnp.asarray(flags), ss, jnp.asarray(wn), jnp.asarray(wa), jnp.asarray(ax))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_event_path_equals_dense_path():
    """Gather-then-scatter (the HBM two-phase path) must equal the dense
    matmul path: the core cross-engine invariant of the whole system."""
    rng = np.random.RandomState(9)
    n, a, steps = 128, 32, 8
    wn = (rng.randint(-50, 50, (n, n)) * (rng.rand(n, n) < 0.15)).astype(np.int32)
    wa = (rng.randint(-50, 50, (a, n)) * (rng.rand(a, n) < 0.4)).astype(np.int32)
    theta = rng.randint(5, 100, n).astype(np.int32)
    nu = np.full(n, -4, np.int32)
    lam = rng.randint(1, 64, n).astype(np.int32)
    flags = rng.randint(0, 4, n).astype(np.int32)

    v_dense = np.zeros(n, np.int32)
    v_event = np.zeros(n, np.int32)
    for t in range(steps):
        ax = (rng.rand(a) < 0.3).astype(np.int32)
        ss = ref.mix_seed(1234, t)
        # dense
        v_dense, s_dense = ref.dense_step_ref(v_dense, theta, nu, lam, flags, ss,
                                              wn, wa, ax)
        v_dense = np.asarray(v_dense)
        # event-driven: neuron_update, then gather fired rows, then scatter
        v2, s2 = ref.neuron_update_ref(v_event, theta, nu, lam, flags, ss)
        v2, s2 = np.asarray(v2), np.asarray(s2)
        np.testing.assert_array_equal(s2, np.asarray(s_dense))
        targets, weights = [], []
        for i in np.nonzero(s2)[0]:
            for j in np.nonzero(wn[i])[0]:
                targets.append(j)
                weights.append(wn[i, j])
        for i in np.nonzero(ax)[0]:
            for j in np.nonzero(wa[i])[0]:
                targets.append(j)
                weights.append(wa[i, j])
        # pad to fixed E with dropped events
        e = 4096
        tgt = np.full(e, n, np.int32)
        wgt = np.zeros(e, np.int32)
        tgt[: len(targets)] = targets
        wgt[: len(weights)] = weights
        v_event = np.asarray(ref.synapse_accum_ref(v2, tgt, wgt))
        np.testing.assert_array_equal(v_event, v_dense)


def test_lowering_shapes():
    lowered = jax.jit(model.neuron_update_fn).lower(*model.neuron_update_spec(1024))
    text = lowered.as_text()
    assert "1024" in text
    lowered = jax.jit(model.synapse_accum_fn).lower(*model.synapse_accum_spec(512, 2048))
    assert lowered is not None
