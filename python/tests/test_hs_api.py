"""hs_api user-API tests: the Fig-6 example network, simulator parity with
the jnp oracle, synapse read/write, and .hsn export round-trip structure.
The v2 (backend-pluggable) surface — backend sessions, step_many, typed
protocol errors — is covered in test_backend_protocol.py and
test_golden_hsn.py; this file pins the classic key-level API."""

import struct

import numpy as np
import jax.numpy as jnp

from compile.kernels import ref
from hs_api import ANN_neuron, CRI_network, LIF_neuron
from hs_api.network import HSN_MAGIC, HSN_MAGIC_V2
from hs_api import simulator as hs_sim


def fig6_network(base_seed=0):
    """The Supplementary A.1 example: neurons a-d, axons alpha/beta."""
    lif_ab = LIF_neuron(theta=3, nu=0, lam=63)
    lif_c = LIF_neuron(theta=4, nu=0, lam=2)
    ann_d = ANN_neuron(theta=5, nu=0, stochastic=True)
    axons = {
        "alpha": [("a", 3), ("c", 2)],
        "beta": [("b", 3)],
    }
    neurons = {
        "a": ([("b", 1), ("d", 2)], lif_ab),
        "b": ([], lif_ab),
        "c": ([], lif_c),
        "d": ([("c", 1)], ann_d),
    }
    return CRI_network(axons, neurons, outputs=["a", "b"], base_seed=base_seed)


def test_fig6_steps():
    net = fig6_network()
    # step 1: alpha+beta fire; a gets +3 (> theta 3? strict: 3 > 3 false)
    fired = net.step(["alpha", "beta"])
    assert fired == []
    assert net.read_membrane("a") == [3]
    assert net.read_membrane("b") == [3]
    # step 2: drive again; a: V=3 noise-free, 3 > 3 false -> no spike yet,
    # leak lam=63 keeps V, then +3 -> 6
    fired = net.step(["alpha", "beta"])
    assert fired == []
    assert net.read_membrane("a") == [6]
    # step 3: no input; a: 6 > 3 -> spike, resets, propagates to b (+1)
    fired = net.step([])
    assert "a" in fired and "b" in fired  # b was at 6 too
    assert net.read_membrane("a") == [0 + 0]  # reset, no inputs
    assert net.read_membrane("b")[0] >= 1  # got a's synapse


def test_simulator_matches_ref_oracle():
    rng = np.random.RandomState(3)
    n, a = 96, 24
    wn = (rng.randint(-60, 60, (n, n)) * (rng.rand(n, n) < 0.2)).astype(np.int32)
    wa = (rng.randint(-60, 60, (a, n)) * (rng.rand(a, n) < 0.5)).astype(np.int32)
    theta = rng.randint(1, 150, n).astype(np.int32)
    nu = rng.randint(-10, 6, n).astype(np.int32)
    lam = rng.randint(0, 64, n).astype(np.int32)
    flags = rng.randint(0, 4, n).astype(np.int32)
    sim = hs_sim.NumpySimulator(wa, wn, theta, nu, lam, flags, base_seed=55)
    v_ref = np.zeros(n, np.int32)
    for t in range(10):
        ax = (rng.rand(a) < 0.35).astype(np.int32)
        s_np = sim.step(ax)
        ss = ref.mix_seed(55, t)
        v_ref, s_jnp = ref.dense_step_ref(v_ref, theta, nu, lam, flags,
                                          jnp.uint32(ss), wn, wa, ax)
        v_ref = np.asarray(v_ref)
        np.testing.assert_array_equal(s_np, np.asarray(s_jnp))
        np.testing.assert_array_equal(sim.v, v_ref)


def test_numpy_prng_matches_jnp():
    for seed in [0, 1, 0xDEADBEEF, 2**32 - 1]:
        for step in [0, 5, 999]:
            assert hs_sim.mix_seed(seed, step) == int(ref.mix_seed(seed, step))
        idx = np.arange(512, dtype=np.uint32)
        np.testing.assert_array_equal(
            hs_sim.noise17(seed, idx), np.asarray(ref.noise17(jnp.uint32(seed), idx))
        )


def test_read_write_synapse():
    net = fig6_network()
    assert net.read_synapse("a", "b") == 1
    net.write_synapse("a", "b", net.read_synapse("a", "b") + 1)
    assert net.read_synapse("a", "b") == 2
    assert net.read_synapse("alpha", "a") == 3
    net.write_synapse("alpha", "a", -5)
    assert net.read_synapse("alpha", "a") == -5
    # dense matrix must track
    assert net.sim.w_axon[net.axon_index["alpha"], net.neuron_index["a"]] == -5


def test_weight_range_validation():
    import pytest
    lif = LIF_neuron(theta=1)
    with pytest.raises(ValueError):
        CRI_network({"x": [("n", 2**15)]}, {"n": ([], lif)}, ["n"])
    with pytest.raises(ValueError):
        LIF_neuron(theta=1, nu=99)
    with pytest.raises(ValueError):
        LIF_neuron(theta=1, lam=64)


def test_v2_surface_on_local_backend():
    """The v2 session surface exists and is coherent on the default
    local backend: named backend, step_many == step loop, no hardware
    cost, idempotent close / context manager."""
    with fig6_network() as net:
        assert net.backend.name == "local"
        assert net.sim is not None  # notebooks poke at the numpy sim
        ref = fig6_network()
        sched = [["alpha", "beta"], ["alpha", "beta"], [], []]
        assert net.step_many(sched) == [ref.step(row) for row in sched]
        assert net.cost() is None
        net.close()  # idempotent


def test_hsn_export_canonical_target_sorted(tmp_path):
    """Per-source synapse order in the .hsn is canonical (sorted by
    target) regardless of definition order — the property that makes
    Python and Rust writes byte-identical."""
    lif = LIF_neuron(theta=9)
    # 'x' lists targets in DESCENDING index order on purpose
    neurons = {
        "a": ([], lif),
        "b": ([], lif),
        "x": ([("b", 5), ("a", 4)], lif),
    }
    net = CRI_network({"in": [("x", 1)]}, neurons, outputs=["x"])
    p = tmp_path / "sorted.hsn"
    net.export_hsn(str(p), version=1)
    blob = p.read_bytes()
    n = 3
    # first adjacency region: neuron 'a' (count 0), 'b' (count 0), then
    # 'x' with 2 records — targets must come out ascending (a=0, b=1)
    off = 8 + 20 + 16 * n
    counts_and_x = struct.unpack_from("<III", blob, off)
    assert counts_and_x == (0, 0, 2)
    t0, w0 = struct.unpack_from("<Ih", blob, off + 12)
    t1, w1 = struct.unpack_from("<Ih", blob, off + 12 + 6)
    assert (t0, w0) == (0, 4), "lower target first after canonicalisation"
    assert (t1, w1) == (1, 5)


def test_hsn_export_header(tmp_path):
    net = fig6_network(base_seed=7)
    p = tmp_path / "fig6.hsn"
    net.export_hsn(str(p), version=1)
    blob = p.read_bytes()
    assert blob[:8] == HSN_MAGIC
    a, n, o, reserved, seed = struct.unpack_from("<IIIIi", blob, 8)
    assert (a, n, o) == (2, 4, 2)
    assert seed == 7
    # params block: 4 x int32 per neuron
    params = np.frombuffer(blob, "<i4", count=4 * n, offset=8 + 20).reshape(n, 4)
    names = net.neuron_keys
    assert params[names.index("a"), 0] == 3  # theta
    assert params[names.index("c"), 2] == 2  # lam
    assert params[names.index("d"), 3] == 2  # ANN stochastic -> FLAG_NOISE


def test_hsn_export_v2_default_layout(tmp_path):
    """The default export is the v2 sectioned layout: magic + header +
    TOC whose sections are 8-byte aligned, ascending and exactly sized
    (rust/src/model_fmt/hsn.rs is the spec)."""
    net = fig6_network(base_seed=7)
    p = tmp_path / "fig6_v2.hsn"
    net.export_hsn(str(p))
    blob = p.read_bytes()
    assert blob[:8] == HSN_MAGIC_V2
    a, n, o, n_sections, seed, reserved = struct.unpack_from("<IIIIiI", blob, 8)
    assert (a, n, o, seed, reserved) == (2, 4, 2, 7, 0)
    assert n_sections == 6
    entries = [struct.unpack_from("<IIQQ", blob, 32 + 24 * k)
               for k in range(n_sections)]
    assert [e[0] for e in entries] == [1, 2, 3, 4, 5, 6]
    prev_end = 32 + 24 * n_sections
    for sid, aux, off, length in entries:
        assert off % 8 == 0
        assert off >= prev_end
        assert off + length <= len(blob)
        prev_end = off + length
    assert prev_end == len(blob)
    # exact section sizes from the header counts
    e = sum(len(s) for s in net.neuron_syns) + sum(len(s) for s in net.axon_syns)
    assert [ent[3] for ent in entries] == [
        16 * n, 4 * (n + 1), 4 * (a + 1), 4 * e, 2 * e, 4 * o,
    ]
    # params section reinterprets directly
    params_off = entries[0][2]
    params = np.frombuffer(blob, "<i4", count=4 * n, offset=params_off).reshape(n, 4)
    names = net.neuron_keys
    assert params[names.index("a"), 0] == 3  # theta
    assert params[names.index("c"), 2] == 2  # lam
