"""Dataset generators + QAT integer-semantics tests."""

import numpy as np
import pytest

from data import dvs_gesture, pong, synth_cifar, synth_mnist
from hs_api import simulator as hs_sim
from train import qat


# ---------------------------------------------------------------- datasets

def test_mnist_deterministic_and_binary():
    a_img, a_lab = synth_mnist.generate(32, seed=5)
    b_img, b_lab = synth_mnist.generate(32, seed=5)
    np.testing.assert_array_equal(a_img, b_img)
    np.testing.assert_array_equal(a_lab, b_lab)
    assert a_img.shape == (32, 28, 28)
    assert set(np.unique(a_img)) <= {0, 1}
    assert a_lab.min() >= 0 and a_lab.max() <= 9
    # every digit renders with some ink, not a full canvas
    on = a_img.reshape(32, -1).mean(1)
    assert (on > 0.02).all() and (on < 0.6).all()


def test_mnist_classes_distinguishable():
    """Same-class images should correlate more than cross-class ones —
    a sanity floor for learnability."""
    imgs, labs = synth_mnist.generate(300, seed=11)
    flat = imgs.reshape(len(imgs), -1).astype(np.float64)
    centroids = np.stack([flat[labs == d].mean(0) for d in range(10)])
    # nearest-centroid accuracy must beat chance comfortably
    pred = ((flat @ centroids.T) / (np.linalg.norm(flat, axis=1, keepdims=True) + 1e-9)
            / (np.linalg.norm(centroids, axis=1) + 1e-9)).argmax(1)
    assert (pred == labs).mean() > 0.4


def test_dvs_gesture_shapes_and_events():
    frames, labs = dvs_gesture.generate(8, seed=2)
    assert frames.shape == (8, 10, 2, 63, 63)
    assert set(np.unique(frames)) <= {0, 1}
    # motion must produce events in most frames
    per_frame = frames.reshape(8, 10, -1).sum(-1)
    assert (per_frame.mean(axis=1) > 10).all()
    assert labs.max() < dvs_gesture.N_CLASSES


def test_cifar_bit_slicing_roundtrip():
    planes, labs = synth_cifar.generate(4, seed=3)
    assert planes.shape == (4, 15, 32, 32)
    assert set(np.unique(planes)) <= {0, 1}
    # bit planes are ordered MSB-first: plane 0 must carry more energy
    # variance than plane 4 for a smooth image
    v0 = planes[:, 0].astype(float).var()
    v4 = planes[:, 4].astype(float).var()
    assert v0 >= 0.0 and v4 >= 0.0  # structural sanity


def test_pong_env_scores_and_dvs():
    env = pong.PongEnv(seed=4)
    total = 0
    for _ in range(500):
        _, r, done = env.step(env.expert_action())
        total += r
        if done:
            break
    # the expert tracks well: should not be losing badly to the noisy opp
    assert env.score[1] >= env.score[0] - 5
    obs = env.dvs_obs()
    assert obs.shape == (2, 84, 84)
    assert obs.sum() > 0  # motion -> events


# ------------------------------------------------------------------- QAT

def test_if_recurrence_matches_hs_api_simulator():
    """The layer-wise IF recurrence (eval path) must equal the full
    NumpySimulator (hardware path) on a single-layer network."""
    rng = np.random.RandomState(0)
    n_in, n_out, t_frames = 12, 5, 6
    w = rng.randint(-40, 40, (n_out, n_in)).astype(np.float64)
    theta = 35
    frames = (rng.rand(t_frames, n_in) < 0.5).astype(np.float64)

    # recurrence path
    t_total = t_frames + 1
    z = np.zeros((t_total, 1, n_out))
    for t in range(t_frames):
        z[t, 0] = w @ frames[t]
    spikes, v = qat.if_recurrence(z, theta)

    # hs_api simulator path: axons -> neurons with IF models
    w_axon = w.T.astype(np.int32)  # [A, N]
    w_neuron = np.zeros((n_out, n_out), np.int32)
    sim = hs_sim.NumpySimulator(
        w_axon,
        w_neuron,
        theta=np.full(n_out, theta, np.int32),
        nu=np.zeros(n_out, np.int32),
        lam=np.full(n_out, 63, np.int32),
        flags=np.full(n_out, hs_sim.FLAG_LIF, np.int32),
    )
    for t in range(t_total):
        ax = frames[t].astype(np.int32) if t < t_frames else np.zeros(n_in, np.int32)
        got = sim.step(ax)
        np.testing.assert_array_equal(got, spikes[t, 0].astype(np.int32), f"step {t}")
    np.testing.assert_array_equal(sim.v, v[0].astype(np.int32))


def test_if_recurrence_negative_leak_quirk():
    """lam=63 floor-division artifact: negative membranes drift +1/step."""
    z = np.zeros((5, 1))
    z[0, 0] = -3.0
    spikes, v = qat.if_recurrence(z, 100.0)
    # after the -3 arrives: -3 -> -2 -> -1 -> 0 (one +1 per later step)
    assert v[0] == 0.0
    assert spikes.sum() == 0


def test_int_forward_binary_strictness():
    # single fc layer, weight 1, theta 0: input 0 -> no spike (0 > 0 false)
    q = [("fc", np.array([[1.0]]), None, None)]
    out = qat.int_forward_binary(q, [0], np.zeros((1, 1, 1, 1)))
    assert out[0, 0] == 0
    out = qat.int_forward_binary(q, [0], np.ones((1, 1, 1, 1)))
    assert out[0, 0] == 1


@pytest.mark.parametrize("scale", [100.0, 8191.0])
def test_layer_scales_headroom(scale):
    import torch.nn as nn

    lin = nn.Linear(4, 2)
    with __import__("torch").no_grad():
        lin.weight.fill_(0.5)
    s = qat.layer_scales([lin], max_scale=scale)[0]
    assert abs(s - scale / 0.5) < 1e-6
