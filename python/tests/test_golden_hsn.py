"""Cross-language HSN parity, Python half (Rust half:
rust/tests/hsn_golden.rs):

* `export_hsn` reproduces the committed golden byte blob exactly;
* the local numpy backend replays the committed spike/membrane
  transcript bit-exactly (so the two language halves pin each other
  through the shared files in testdata/);
* `step_many` equals the equivalent `step` loop on the local backend.
"""

import json
import os

import pytest

TESTDATA = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..", "testdata")


def load_transcript():
    with open(os.path.join(TESTDATA, "fig6_golden_transcript.json")) as f:
        return json.load(f)


def golden_network(backend="local"):
    import tools.gen_golden_hsn as gen  # the committed generator is the spec

    return gen.fig6_network(backend=backend)


def test_export_hsn_reproduces_golden_bytes(tmp_path):
    with open(os.path.join(TESTDATA, "fig6_golden.hsn"), "rb") as f:
        want = f.read()
    net = golden_network()
    p = tmp_path / "fig6.hsn"
    net.export_hsn(str(p), version=1)
    got = p.read_bytes()
    assert got == want, (
        "export_hsn bytes diverged from testdata/fig6_golden.hsn — if the "
        "format changed deliberately, regenerate with "
        "python3 python/tools/gen_golden_hsn.py and update the Rust side"
    )


def test_export_hsn_v2_reproduces_golden_bytes(tmp_path):
    """The default (v2) export is byte-pinned cross-language too: the
    Rust side asserts `hsn_v2_bytes` reproduces the same blob."""
    with open(os.path.join(TESTDATA, "fig6_golden_v2.hsn"), "rb") as f:
        want = f.read()
    net = golden_network()
    p = tmp_path / "fig6_v2.hsn"
    net.export_hsn(str(p))  # version=2 is the default
    got = p.read_bytes()
    assert got[:8] == b"HSNET2\x00\x00"
    assert got == want, (
        "export_hsn v2 bytes diverged from testdata/fig6_golden_v2.hsn — "
        "if the format changed deliberately, regenerate with "
        "python3 python/tools/gen_golden_hsn.py and update the Rust side"
    )


def test_local_backend_replays_golden_transcript():
    t = load_transcript()
    net = golden_network()
    assert net.n_neurons == t["n_neurons"] and net.n_axons == t["n_axons"]
    all_ids = list(range(net.n_neurons))
    for step, axon_ids in enumerate(t["stimulus"]):
        fired = net.backend.step(axon_ids)
        assert fired == t["output_spikes"][step], f"step {step}: output spikes"
        assert net.backend.read_membrane(all_ids) == t["membranes"][step], (
            f"step {step}: membranes"
        )


def test_step_many_matches_step_loop_locally():
    t = load_transcript()
    looped = golden_network()
    batched = golden_network()
    want = [looped.backend.step(row) for row in t["stimulus"]]
    got = batched.backend.step_many(t["stimulus"])
    assert got == want
    all_ids = list(range(looped.n_neurons))
    assert batched.backend.read_membrane(all_ids) == looped.backend.read_membrane(all_ids)

    # and through the key-mapping layer
    key_sched = [["alpha", "beta"], ["beta"], [], []]
    a = golden_network()
    b = golden_network()
    assert a.step_many(key_sched) == [b.step(row) for row in key_sched]


def test_generator_is_in_sync_with_testdata(tmp_path):
    """Running the committed generator must be a no-op against testdata
    (guards against editing one side and forgetting the other)."""
    import tools.gen_golden_hsn as gen

    net = gen.fig6_network()
    sched = gen.stimulus_schedule(net.n_axons)
    t = load_transcript()
    assert sched == t["stimulus"], "generator stimulus drifted from committed transcript"
    assert gen.BASE_SEED == t["base_seed"]


@pytest.mark.skipif(
    __import__("hs_api").find_server_binary() is None,
    reason="no hiaer-spike binary in this environment",
)
def test_rust_backend_replays_golden_transcript():
    """Full cross-language loop when a server binary is available: the
    Rust session backend replays the numpy-generated transcript."""
    t = load_transcript()
    with golden_network(backend="rust") as net:
        got = net.backend.step_many(t["stimulus"])
        assert got == t["output_spikes"]
        all_ids = list(range(net.n_neurons))
        assert net.backend.read_membrane(all_ids) == t["membranes"][-1]
