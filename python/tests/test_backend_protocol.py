"""Session-protocol client + backend-pluggability tests against fake
transports/backends — no Rust subprocess required. The wire format is
rust/src/sim/session.rs; these tests pin the client half: greeting
checks, request marshalling (one line per request, one line per
*batch*), and the stable-code -> typed-exception mapping."""

import json

import pytest

from hs_api import (
    CRI_network,
    HsBackendUnavailable,
    HsProtocolError,
    HsSessionError,
    HsStimulusError,
    LIF_neuron,
    SessionClient,
)
from hs_api.backend import SimBackend, make_backend, LocalBackend, RustSessionBackend
from hs_api.exceptions import HsQuotaError, error_from_code


HELLO = {"ok": True, "op": "hello", "protocol": 1, "backend": "rust"}


class FakeTransport:
    """Scripted transport: canned response lines, recorded sends."""

    def __init__(self, responses, hello=True):
        self.responses = ([json.dumps(HELLO)] if hello else []) + list(responses)
        self.sent = []
        self.closed = False

    def send_line(self, line):
        self.sent.append(line)

    def recv_line(self):
        if not self.responses:
            raise HsProtocolError("server closed the connection", code="closed")
        return self.responses.pop(0)

    def close(self):
        self.closed = True


def client_with(*responses):
    return SessionClient(FakeTransport([json.dumps(r) for r in responses]))


# ------------------------------------------------------------ hello / framing


def test_hello_is_consumed_and_version_checked():
    t = FakeTransport([])
    c = SessionClient(t)
    assert c.server_backend == "rust"
    assert t.sent == []  # greeting is read, nothing sent


def test_protocol_version_mismatch_raises():
    bad = dict(HELLO, protocol=99)
    with pytest.raises(HsProtocolError, match="version mismatch"):
        SessionClient(FakeTransport([json.dumps(bad)], hello=False))


def test_missing_hello_raises():
    with pytest.raises(HsProtocolError, match="hello"):
        SessionClient(FakeTransport([json.dumps({"ok": True, "op": "step"})], hello=False))


def test_unparseable_server_line_raises_protocol_error():
    t = FakeTransport(["{nope"])
    c = SessionClient(t)
    with pytest.raises(HsProtocolError, match="unparseable"):
        c.reset()


def test_closed_stream_raises_protocol_error():
    c = SessionClient(FakeTransport([]))
    with pytest.raises(HsProtocolError) as ei:
        c.step([0])
    assert ei.value.code == "closed"


# ------------------------------------------------------- request marshalling


def test_step_sends_one_line_and_returns_spikes():
    c = client_with({"ok": True, "op": "step", "spikes": [1, 3], "fired": 4})
    assert c.step([0, 2]) == [1, 3]
    sent = json.loads(c.transport.sent[-1])
    assert sent == {"op": "step", "axons": [0, 2]}


def test_step_many_sends_single_line_per_batch():
    c = client_with({"ok": True, "op": "step_many", "spikes": [[], [1], [0, 1]],
                     "fired_total": 5})
    batch = [[0], [], [0, 1]]
    assert c.step_many(batch) == [[], [1], [0, 1]]
    assert len(c.transport.sent) == 1, "a batch must cross the wire as ONE line"
    sent = json.loads(c.transport.sent[0])
    assert sent == {"op": "step_many", "batch": batch}


def test_configure_and_cost_round_trip():
    c = client_with(
        {"ok": True, "op": "configure", "protocol": 1, "backend": "rust",
         "neurons": 4, "axons": 2, "outputs": 2},
        {"ok": True, "op": "cost", "energy_uj": 1.5, "latency_us": 0.25,
         "hbm_rows": 7, "events": 9, "cycles": 410, "backend": "rust"},
    )
    conf = c.configure("/tmp/net.hsn", seed=7)
    assert conf["neurons"] == 4
    assert json.loads(c.transport.sent[0]) == {
        "op": "configure", "net": "/tmp/net.hsn", "seed": 7}
    cost = c.cost()
    assert cost == {"energy_uj": 1.5, "latency_us": 0.25, "hbm_rows": 7,
                    "events": 9, "cycles": 410, "backend": "rust"}


def test_configure_workers_field_is_optional_and_forwarded():
    ok = {"ok": True, "op": "configure", "protocol": 1, "backend": "rust",
          "neurons": 4, "axons": 2, "outputs": 2}
    c = client_with(ok)
    c.configure("/tmp/net.hsn", workers=4)
    assert json.loads(c.transport.sent[0]) == {
        "op": "configure", "net": "/tmp/net.hsn", "workers": 4}
    # omitted -> not on the wire (server default applies)
    c2 = client_with(ok)
    c2.configure("/tmp/net.hsn")
    assert "workers" not in json.loads(c2.transport.sent[0])
    # the server rejects workers=0 with the stable `config` code
    c3 = client_with({"ok": False, "code": "config",
                      "error": "workers must be >= 1"})
    with pytest.raises(HsSessionError, match=">= 1"):
        c3.configure("/tmp/net.hsn", workers=0)


def test_configure_shards_field_is_optional_and_forwarded():
    ok = {"ok": True, "op": "configure", "protocol": 1, "backend": "sharded",
          "neurons": 4, "axons": 2, "outputs": 2}
    c = client_with(ok)
    c.configure("/tmp/net.hsn", shards=2)
    assert json.loads(c.transport.sent[0]) == {
        "op": "configure", "net": "/tmp/net.hsn", "shards": 2}
    # composes with the other optional knobs on one wire line
    c2 = client_with(ok)
    c2.configure("/tmp/net.hsn", seed=7, workers=2, shards=4)
    assert json.loads(c2.transport.sent[0]) == {
        "op": "configure", "net": "/tmp/net.hsn", "seed": 7,
        "workers": 2, "shards": 4}
    # omitted -> not on the wire (server keeps its configured backend)
    c3 = client_with(ok)
    c3.configure("/tmp/net.hsn")
    assert "shards" not in json.loads(c3.transport.sent[0])
    # the server rejects shards=0 / shards > cores with the `config` code
    c4 = client_with({"ok": False, "code": "config",
                      "error": "shards must be >= 1"})
    with pytest.raises(HsSessionError, match="shards must be >= 1"):
        c4.configure("/tmp/net.hsn", shards=0)


def test_configure_learning_field_is_optional_and_forwarded():
    ok = {"ok": True, "op": "configure", "protocol": 1, "backend": "rust",
          "neurons": 4, "axons": 2, "outputs": 2}
    # any subset of the integer knobs goes on the wire verbatim (ints)
    c = client_with(ok)
    c.configure("/tmp/net.hsn", learning={"a_plus": 8, "w_max": 64.0})
    assert json.loads(c.transport.sent[0]) == {
        "op": "configure", "net": "/tmp/net.hsn",
        "learning": {"a_plus": 8, "w_max": 64}}
    # omitted -> not on the wire (learning stays off)
    c2 = client_with(ok)
    c2.configure("/tmp/net.hsn")
    assert "learning" not in json.loads(c2.transport.sent[0])
    # the server validates the rule with the stable `config` code
    c3 = client_with({"ok": False, "code": "config",
                      "error": "learning: a_plus must be >= 0"})
    with pytest.raises(HsSessionError, match="a_plus"):
        c3.configure("/tmp/net.hsn", learning={"a_plus": -1})


def test_write_synapse_marshals_and_strips_envelope():
    c = client_with(
        {"ok": True, "op": "write_synapse", "created": False,
         "compacted": False},
        {"ok": True, "op": "write_synapse", "created": True,
         "compacted": False},
    )
    out = c.write_synapse(0, 2, 7)
    # pre_is_axon defaults to False and is always explicit on the wire
    assert json.loads(c.transport.sent[0]) == {
        "op": "write_synapse", "pre": 0, "post": 2, "weight": 7,
        "pre_is_axon": False}
    assert out == {"created": False, "compacted": False}
    out = c.write_synapse(1, 3, -4, pre_is_axon=True)
    assert json.loads(c.transport.sent[1]) == {
        "op": "write_synapse", "pre": 1, "post": 3, "weight": -4,
        "pre_is_axon": True}
    assert out == {"created": True, "compacted": False}


def test_write_synapse_quota_code_maps_to_quota_error():
    c = client_with({"ok": False, "code": "quota",
                     "error": "write_synapse budget exhausted (8 per step)"})
    with pytest.raises(HsQuotaError, match="budget"):
        c.write_synapse(0, 1, 5)


# ----------------------------------------------- stable codes -> exceptions


@pytest.mark.parametrize(
    "code,exc",
    [
        ("stimulus", HsStimulusError),
        ("backend_unavailable", HsBackendUnavailable),
        ("malformed_request", HsProtocolError),
        ("unknown_op", HsProtocolError),
        ("oversized_batch", HsProtocolError),
        ("no_session", HsSessionError),
        ("config", HsSessionError),
        ("engine", HsSessionError),
    ],
)
def test_error_codes_map_to_typed_exceptions(code, exc):
    c = client_with({"ok": False, "code": code, "error": f"boom ({code})"})
    with pytest.raises(exc) as ei:
        c.step([0])
    assert ei.value.code == code
    assert code in str(ei.value)


def test_unknown_future_code_degrades_to_session_error():
    err = error_from_code("quantum_flux", "novel failure")
    assert isinstance(err, HsSessionError)
    assert err.code == "quantum_flux"


def test_error_recovery_session_stays_usable():
    c = client_with(
        {"ok": False, "code": "stimulus", "error": "axon id 9 out of range"},
        {"ok": True, "op": "step", "spikes": [0], "fired": 1},
    )
    with pytest.raises(HsStimulusError):
        c.step([9])
    assert c.step([0]) == [0]  # next request proceeds over the same session


# --------------------------------------------------- CRI_network + backends


def fig6(backend="local"):
    lif_ab = LIF_neuron(theta=3, nu=0, lam=63)
    axons = {"alpha": [("a", 3)], "beta": [("b", 3)]}
    neurons = {"a": ([("b", 1)], lif_ab), "b": ([], lif_ab)}
    return CRI_network(axons, neurons, outputs=["b", "a"], base_seed=0,
                       backend=backend)


class RecordingBackend(SimBackend):
    """Minimal fake backend: records calls, spikes everything asked."""

    name = "recording"

    def __init__(self, fired):
        self.fired = fired
        self.calls = []

    def configure(self, network):
        self.calls.append(("configure", network.n_neurons, network.n_axons))

    def step(self, axon_ids):
        self.calls.append(("step", list(axon_ids)))
        return list(self.fired)

    def read_membrane(self, ids):
        self.calls.append(("read_membrane", list(ids)))
        return [0] * len(ids)

    def reset(self):
        self.calls.append(("reset",))

    def write_synapse(self, *a):
        self.calls.append(("write_synapse", *a))


def test_network_maps_keys_to_global_ids_and_back():
    b = RecordingBackend(fired=[0, 1])
    net = fig6(backend=b)
    assert b.calls[0] == ("configure", 2, 2)
    fired = net.step(["beta", "alpha"])
    # axon keys map to indices in construction order; fired ids map back
    # to keys in OUTPUTS-LIST order (the paper API's step contract)
    assert b.calls[-1] == ("step", [1, 0])
    assert fired == ["b", "a"]


def test_network_step_unknown_axon_key_raises_keyerror():
    net = fig6()
    with pytest.raises(KeyError):
        net.step(["gamma"])


def test_make_backend_resolution():
    assert isinstance(make_backend("local"), LocalBackend)
    assert isinstance(make_backend("rust"), RustSessionBackend)
    b = LocalBackend()
    assert make_backend(b) is b
    with pytest.raises(ValueError, match="unknown backend"):
        make_backend("fpga9000")


def test_local_backend_parity_step_vs_step_many_and_reset():
    a, b = fig6(), fig6()
    sched = [["alpha", "beta"], ["alpha"], [], ["beta"], []]
    want = [a.step(row) for row in sched]
    assert b.step_many(sched) == want
    assert b.read_membrane("a", "b") == a.read_membrane("a", "b")
    b.reset()
    assert b.read_membrane("a", "b") == [0, 0]
    assert b.step_many(sched) == want, "post-reset replay is deterministic"


def test_local_backend_rejects_out_of_range_ids():
    net = fig6()
    with pytest.raises(HsStimulusError):
        net.backend.step([-1])  # no silent numpy wraparound
    with pytest.raises(HsStimulusError):
        net.backend.step([2])
    with pytest.raises(HsStimulusError):
        net.backend.read_membrane([-1])  # same class as the rust backend
    with pytest.raises(HsStimulusError):
        net.backend.read_membrane([9])
    # batch validation is atomic: a bad row mid-batch executes nothing
    v0 = net.backend.read_membrane([0, 1])
    with pytest.raises(HsStimulusError):
        net.backend.step_many([[0], [5], [1]])
    assert net.backend.read_membrane([0, 1]) == v0


def test_step_many_client_chunks_oversized_schedules(monkeypatch):
    import hs_api.session as session_mod

    monkeypatch.setattr(session_mod, "MAX_BATCH_STEPS", 2)
    c = client_with(
        {"ok": True, "op": "step_many", "spikes": [[0], [1]], "fired_total": 2},
        {"ok": True, "op": "step_many", "spikes": [[0, 1]], "fired_total": 2},
    )
    got = c.step_many([[0], [], [0, 1]])
    assert got == [[0], [1], [0, 1]], "chunk results concatenate in order"
    sent = [json.loads(s) for s in c.transport.sent]
    assert [len(s["batch"]) for s in sent] == [2, 1], "split at the server cap"


def test_write_synapse_rolls_back_on_backend_failure():
    class ExplodingBackend(RecordingBackend):
        def write_synapse(self, *a):
            raise RuntimeError("session died")

    net = fig6(backend=ExplodingBackend(fired=[]))
    before = net.read_synapse("alpha", "a")
    with pytest.raises(RuntimeError):
        net.write_synapse("alpha", "a", before + 1)
    assert net.read_synapse("alpha", "a") == before, (
        "definition must not diverge from the live session"
    )


def test_rust_backend_without_binary_is_unavailable(monkeypatch):
    import hs_api.backend as backend_mod

    monkeypatch.setattr(backend_mod, "find_server_binary", lambda: None)
    monkeypatch.delenv("HS_BIN", raising=False)
    with pytest.raises(HsBackendUnavailable):
        fig6(backend="rust")


def test_rust_backend_failed_configure_cleans_up(monkeypatch):
    """A configure that fails inside CRI_network.__init__ must not leak
    the session or the exported temp .hsn (nobody holds the backend to
    close() it afterwards)."""
    import os

    class FakeClient:
        def __init__(self):
            self.closed = False

        def configure(self, *a, **k):
            raise HsSessionError("backend `xla` is unavailable", code="backend_unavailable")

        def close(self):
            self.closed = True

    fake = FakeClient()
    b = RustSessionBackend()
    monkeypatch.setattr(b, "_launch", lambda: fake)
    with pytest.raises(HsSessionError):
        fig6(backend=b)
    assert fake.closed, "session client must be closed on failed configure"
    assert b._hsn_path is None or not os.path.exists(b._hsn_path), "temp .hsn leaked"
    # later calls on the torn-down backend raise a typed error, not
    # AttributeError on a None client
    with pytest.raises(HsSessionError, match="session closed"):
        b.step([0])
    with pytest.raises(HsSessionError, match="session closed"):
        b.cost()


def test_rust_backend_step_many_validates_batch_before_sending(monkeypatch):
    """Atomicity parity with the local backend: a bad row anywhere in the
    schedule is rejected before ANY chunk crosses the wire."""

    class NoSendClient:
        def step_many(self, batch):
            raise AssertionError("batch must not be sent")

    b = RustSessionBackend()
    b._client = NoSendClient()
    b._network = fig6()  # n_axons == 2
    with pytest.raises(HsStimulusError):
        b.step_many([[0], [5], [1]])
    with pytest.raises(HsStimulusError):
        b.step_many([[-1]])
    # single-step path raises the same class (not a wire-level
    # malformed_request), and a closed session never resurrects on
    # write_synapse
    with pytest.raises(HsStimulusError):
        b.step([-1])
    b._client = None
    with pytest.raises(HsSessionError, match="session closed"):
        b.write_synapse(True, 0, 0, 3, 4)
