"""Client-side tests for the binary stimulus/spike wire (wire v2):
negotiation at ``configure`` (including the old-server fallback path),
struct-level STIM packing / SPIKES unpacking, JSON error lines on the
binary wire, and the chunked ``step_many`` atomicity regression — a bad
axon id in the *last* chunk of a multi-chunk schedule must execute zero
steps.

Everything runs against scripted fakes — no Rust binary required; the
server half (and the stdio/TCP parity pins) lives in
``rust/src/sim/session.rs`` and ``rust/tests/serve_tcp.rs``."""

import json
import struct

import pytest

import hs_api.session as session_mod
from hs_api import (
    HsProtocolError,
    HsStimulusError,
    HsWireNegotiationError,
    SessionClient,
)
from hs_api.session import (
    FRAME_SPIKES,
    FRAME_STIM,
    WIRE_SENTINEL,
    _pack_stim_frame,
    _unpack_spikes_payload,
)

HELLO = {"ok": True, "op": "hello", "protocol": 1, "backend": "rust"}

CONFIGURED_BINARY = {
    "ok": True, "op": "configure", "protocol": 1, "backend": "rust",
    "neurons": 4, "axons": 4, "outputs": 2, "wire": "binary",
}


class FakeWireTransport:
    """Scripted byte-stream transport: one response buffer that JSON
    lines and binary frames are both consumed from, with every send
    recorded."""

    def __init__(self, script: bytes = b"", hello: bool = True):
        if hello:
            script = (json.dumps(HELLO) + "\n").encode("utf-8") + script
        self.buf = script
        self.sent_lines = []
        self.sent_bytes = []
        self.closed = False

    def feed(self, more: bytes) -> None:
        self.buf += more

    def feed_line(self, resp: dict) -> None:
        self.buf += (json.dumps(resp) + "\n").encode("utf-8")

    def send_line(self, line):
        self.sent_lines.append(line)

    def send_bytes(self, data):
        self.sent_bytes.append(data)

    def recv_line(self):
        i = self.buf.find(b"\n")
        if i < 0:
            raise HsProtocolError("server closed the connection", code="closed")
        line, self.buf = self.buf[:i], self.buf[i + 1:]
        return line.decode("utf-8")

    def recv_exact(self, n):
        if len(self.buf) < n:
            raise HsProtocolError("server closed mid-frame", code="closed")
        out, self.buf = self.buf[:n], self.buf[n:]
        return out

    def close(self):
        self.closed = True


def spikes_reply(rows, fired_total=0) -> bytes:
    """A complete server SPIKES wire frame for the given output rows."""
    payload = struct.pack("<QI", fired_total, len(rows))
    for r in rows:
        payload += struct.pack("<I", len(r))
        if r:
            payload += struct.pack(f"<{len(r)}I", *r)
    return WIRE_SENTINEL + struct.pack("<I", len(payload) + 1) + bytes([FRAME_SPIKES]) + payload


def binary_client(t: FakeWireTransport) -> SessionClient:
    t.feed_line(CONFIGURED_BINARY)
    c = SessionClient(t, wire="binary")
    c.configure("net.hsn")
    return c


# ------------------------------------------------------------ negotiation


def test_configure_sends_wire_field_and_honours_echo():
    t = FakeWireTransport()
    c = binary_client(t)
    req = json.loads(t.sent_lines[0])
    assert req["op"] == "configure"
    assert req["wire"] == "binary"
    assert c._wire_binary is True
    assert c._n_axons == 4


def test_json_wire_default_sends_no_wire_field():
    t = FakeWireTransport()
    t.feed_line({**CONFIGURED_BINARY, "wire": "json"})
    c = SessionClient(t)
    c.configure("net.hsn")
    assert "wire" not in json.loads(t.sent_lines[0])
    assert c._wire_binary is False


def test_old_server_missing_echo_raises_negotiation_error():
    # an old server ignores unknown configure fields: ok response, no echo
    old_style = {k: v for k, v in CONFIGURED_BINARY.items() if k != "wire"}
    t = FakeWireTransport()
    t.feed_line(old_style)
    c = SessionClient(t, wire="binary")
    with pytest.raises(HsWireNegotiationError, match="did not acknowledge"):
        c.configure("net.hsn")
    # the typed error is still a protocol error for coarse handlers
    assert issubclass(HsWireNegotiationError, HsProtocolError)
    assert c._wire_binary is False, "negotiation failure must not half-enable binary"


def test_wire_argument_is_validated():
    with pytest.raises(ValueError, match="wire"):
        SessionClient(FakeWireTransport(), wire="carrier-pigeon")


# ------------------------------------------------------- packing / framing


def test_stim_frame_layout_is_exact():
    frame = _pack_stim_frame([[0, 1], [], [7]])
    payload = (
        struct.pack("<I", 3)
        + struct.pack("<I", 2) + struct.pack("<2I", 0, 1)
        + struct.pack("<I", 0)
        + struct.pack("<I", 1) + struct.pack("<I", 7)
    )
    assert frame == WIRE_SENTINEL + struct.pack("<I", len(payload) + 1) + bytes([FRAME_STIM]) + payload


def test_step_many_binary_round_trip():
    t = FakeWireTransport()
    c = binary_client(t)
    t.feed(spikes_reply([[1], [], [0, 1]], fired_total=5))
    assert c.step_many([[0, 1], [2], []]) == [[1], [], [0, 1]]
    # the stimulus travelled as one packed frame, not a JSON line
    assert t.sent_bytes == [_pack_stim_frame([[0, 1], [2], []])]
    assert len(t.sent_lines) == 1, "only the configure line goes as JSON"


def test_binary_error_reply_is_a_typed_json_line():
    t = FakeWireTransport()
    c = binary_client(t)
    # errors are ALWAYS JSON lines, even on the binary wire
    t.feed_line({"ok": False, "code": "quota", "error": "batch too long"})
    from hs_api import HsQuotaError

    with pytest.raises(HsQuotaError):
        c.step_many([[0]])


def test_unexpected_reply_kind_is_protocol_error():
    t = FakeWireTransport()
    c = binary_client(t)
    bad = WIRE_SENTINEL + struct.pack("<I", 2) + bytes([0x77, 0x00])
    t.feed(bad)
    with pytest.raises(HsProtocolError, match="0x77"):
        c.step_many([[0]])


def test_spikes_unpack_rejects_truncation_and_trailers():
    good = struct.pack("<QI", 2, 1) + struct.pack("<I", 2) + struct.pack("<2I", 3, 9)
    assert _unpack_spikes_payload(good) == ([[3, 9]], 2)
    with pytest.raises(HsProtocolError, match="truncated"):
        _unpack_spikes_payload(good[:-1])
    with pytest.raises(HsProtocolError, match="trailing"):
        _unpack_spikes_payload(good + b"\x00")
    with pytest.raises(HsProtocolError, match="truncated"):
        _unpack_spikes_payload(b"\x00" * 4)  # shorter than the fixed header


# ------------------------------------------- chunked step_many atomicity


def test_bad_id_in_last_chunk_executes_zero_steps(monkeypatch):
    """Regression: the client splits long schedules into
    MAX_BATCH_STEPS-sized requests; a bad axon id in the *last* chunk
    used to surface only after earlier chunks had already executed.
    Whole-schedule validation must reject before anything is sent."""
    monkeypatch.setattr(session_mod, "MAX_BATCH_STEPS", 2)
    t = FakeWireTransport()
    t.feed_line({**CONFIGURED_BINARY, "wire": "json"})
    c = SessionClient(t)
    c.configure("net.hsn")
    sent_before = len(t.sent_lines)
    with pytest.raises(HsStimulusError, match="axon id 99") as ei:
        c.step_many([[0], [1], [99]])  # 2 chunks; bad id in chunk 2
    assert ei.value.code == "stimulus"
    assert len(t.sent_lines) == sent_before, "no chunk may reach the wire"
    assert t.sent_bytes == []


def test_bad_id_in_last_chunk_executes_zero_steps_binary(monkeypatch):
    monkeypatch.setattr(session_mod, "MAX_BATCH_STEPS", 2)
    t = FakeWireTransport()
    c = binary_client(t)
    with pytest.raises(HsStimulusError):
        c.step_many([[0], [1], [99]])
    assert t.sent_bytes == [], "no frame may reach the wire"


def test_in_range_schedule_still_chunks(monkeypatch):
    monkeypatch.setattr(session_mod, "MAX_BATCH_STEPS", 2)
    t = FakeWireTransport()
    c = binary_client(t)
    t.feed(spikes_reply([[0], [1]]))
    t.feed(spikes_reply([[]]))
    assert c.step_many([[0], [1], [2]]) == [[0], [1], []]
    assert len(t.sent_bytes) == 2, "3 steps at cap 2 = 2 STIM frames"
