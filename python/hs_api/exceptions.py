"""Typed hs_api exceptions, keyed by the session protocol's stable error
codes (rust/src/sim/session.rs — the wire contract).

Every exception carries ``.code``: the machine-readable protocol code
that produced it (``None`` for purely client-side failures). Backends
raise these instead of bare ``RuntimeError`` so callers can distinguish
"your stimulus was bad" from "the engine is missing" programmatically.
"""

from __future__ import annotations


class HsError(Exception):
    """Base class for every hs_api error."""

    def __init__(self, message: str, code: str | None = None):
        super().__init__(message)
        self.code = code


class HsBackendUnavailable(HsError):
    """The requested backend cannot run here (server binary missing,
    build lacks a feature, subprocess died on launch)."""


class HsStimulusError(HsError):
    """Malformed runtime input: out-of-range axon or neuron id."""


class HsProtocolError(HsError):
    """The wire itself broke: unparseable line, unknown op, oversized
    batch, protocol-version mismatch, or the server closed the stream."""


class HsWireNegotiationError(HsProtocolError):
    """``wire="binary"`` was requested but the server did not
    acknowledge it — an old server ignores unknown ``configure`` fields
    and omits the ``wire`` echo from its response. Reconnect with
    ``wire="json"`` (the default) to talk to that server."""


class HsSessionError(HsError):
    """Session-level failure: no configured simulator, bad network file,
    an engine error inside the server, or an eviction (``evicted``)."""


class HsServerBusy(HsError):
    """The shared server cannot take the work right now — admission
    rejected the connection (``server_busy``) or the per-request compute
    deadline expired while queued (``deadline``). Retryable: back off
    and try again (or another instance)."""


class HsQuotaError(HsError):
    """A per-session quota rejected the request (``quota``): network
    larger than ``max_neurons``, or a ``step_many`` batch longer than
    ``max_batch``. Not retryable as-is — shrink the request."""


# protocol code -> exception class (codes are defined in
# rust/src/sim/session.rs; unknown codes map to HsSessionError so a
# newer server never crashes an older client with a KeyError)
_CODE_MAP = {
    "stimulus": HsStimulusError,
    "backend_unavailable": HsBackendUnavailable,
    "malformed_request": HsProtocolError,
    "unknown_op": HsProtocolError,
    "oversized_batch": HsProtocolError,
    "no_session": HsSessionError,
    "config": HsSessionError,
    "engine": HsSessionError,
    "quota": HsQuotaError,
    "server_busy": HsServerBusy,
    "deadline": HsServerBusy,
    "evicted": HsSessionError,
}


def error_from_code(code: str, message: str) -> HsError:
    """Build the typed exception for a server-reported error code."""
    cls = _CODE_MAP.get(code, HsSessionError)
    return cls(message, code=code)
