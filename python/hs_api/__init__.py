"""hs_api — the HiAER-Spike user-facing Python network API (paper §5).

Networks are *defined* once with :class:`CRI_network` and *executed* on
a per-session backend: the local Fig-8 numpy simulator
(``backend="local"``, the default) or any engine behind the Rust
``Simulator`` facade via the JSON-lines session protocol
(``backend="rust"`` — spawns ``hiaer-spike serve-session``). The
`.hsn` export remains the hand-off format the Rust coordinator compiles
into the HBM synaptic routing table. See README.md in this package for
the local-vs-rust walkthrough.
"""

from .backend import (  # noqa: F401
    LocalBackend,
    RustSessionBackend,
    SimBackend,
    make_backend,
)
from .exceptions import (  # noqa: F401
    HsBackendUnavailable,
    HsError,
    HsProtocolError,
    HsQuotaError,
    HsServerBusy,
    HsSessionError,
    HsStimulusError,
    HsWireNegotiationError,
)
from .network import CRI_network  # noqa: F401
from .neuron_models import ANN_neuron, LIF_neuron  # noqa: F401
from .session import (  # noqa: F401
    SessionClient,
    SubprocessTransport,
    TcpTransport,
    find_server_binary,
)
from .simulator import NumpySimulator  # noqa: F401
