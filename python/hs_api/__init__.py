"""hs_api — the HiAER-Spike user-facing Python network API (paper §5).

Build/author-time only: this package is used to define networks, simulate
them on the local machine (the Fig-8 numpy simulator), and export them to
the `.hsn` network format that the Rust coordinator compiles into the HBM
synaptic routing table. It is never on the accelerated request path.
"""

from .neuron_models import ANN_neuron, LIF_neuron  # noqa: F401
from .network import CRI_network  # noqa: F401
from .simulator import NumpySimulator  # noqa: F401
