"""Fig-8 numpy software simulator — bit-exact with compile.kernels.ref
and with the Rust engines (the `hs_api.backend.LocalBackend` wraps this;
the cross-language golden transcript in testdata/ pins the parity).

Sparse weight matrices are stored as CSR-ish (indices per row) but the
update itself follows the exact phase order of the hardware:
noise -> threshold/reset -> leak -> integrate (same step's spikes).

int32 arithmetic wraps (numpy semantics) exactly like the int32 HLO and
the Rust engines (wrapping_add).
"""

from __future__ import annotations

import numpy as np

PHI32 = np.uint32(0x9E3779B9)
FLAG_LIF = 1
FLAG_NOISE = 2


def mix_seed(base_seed: int, step: int) -> int:
    """Per-step seed; matches ref.mix_seed / rust util::prng::mix_seed."""
    x = np.uint32((int(base_seed) ^ ((int(step) * 0x9E3779B9) & 0xFFFFFFFF)) & 0xFFFFFFFF)
    with np.errstate(over="ignore"):
        x = x ^ (x << np.uint32(13))
        x = x ^ (x >> np.uint32(17))
        x = x ^ (x << np.uint32(5))
    return int(x | np.uint32(1))


def noise17(step_seed: int, idx: np.ndarray) -> np.ndarray:
    """Vectorised 17-bit odd noise; matches ref.noise17."""
    x = np.uint32(step_seed) ^ (idx.astype(np.uint32) * PHI32)
    for _ in range(2):
        x = x ^ (x << np.uint32(13))
        x = x ^ (x >> np.uint32(17))
        x = x ^ (x << np.uint32(5))
    lo = (x & np.uint32(0x1FFFF)).astype(np.int32)
    return (lo - np.int32(1 << 16)) | np.int32(1)


class NumpySimulator:
    """Dense-matrix software simulation of one HiAER-Spike core."""

    def __init__(self, w_axon, w_neuron, theta, nu, lam, flags, base_seed=0):
        self.w_axon = np.asarray(w_axon, np.int32)  # [A, N] pre-major
        self.w_neuron = np.asarray(w_neuron, np.int32)  # [N, N]
        self.theta = np.asarray(theta, np.int32)
        self.nu = np.asarray(nu, np.int32)
        self.lam = np.asarray(lam, np.int32)
        self.flags = np.asarray(flags, np.int32)
        self.n = self.w_neuron.shape[0]
        self.v = np.zeros(self.n, np.int32)
        self.base_seed = base_seed
        self.step_num = 0

    def reset(self):
        self.v[:] = 0
        self.step_num = 0

    def step(self, axon_in: np.ndarray):
        """One timestep. axon_in: 0/1 int vector [A]. Returns spike vec [N]."""
        v = self.v
        ss = mix_seed(self.base_seed, self.step_num)

        # 1. noise
        xi = noise17(ss, np.arange(self.n, dtype=np.uint32))
        nu = self.nu
        with np.errstate(over="ignore"):
            left = np.clip(nu, 0, 31).astype(np.int32)
            right = np.clip(-nu, 0, 31).astype(np.int32)
            shifted = np.where(nu >= 0, xi << left, xi >> right).astype(np.int32)
            noisy = (self.flags & FLAG_NOISE) != 0
            v = np.where(noisy, v + shifted, v)

            # 2. spike + reset (strict >)
            spikes = (v > self.theta).astype(np.int32)
            v = np.where(spikes != 0, np.int32(0), v)

            # 3. leak / clear
            lam_c = np.clip(self.lam, 0, 31).astype(np.int32)
            is_lif = (self.flags & FLAG_LIF) != 0
            v = np.where(is_lif, v - (v >> lam_c), np.int32(0))

            # 4. integrate this step's spikes + axon inputs
            contrib = spikes @ self.w_neuron
            contrib = contrib + np.asarray(axon_in, np.int32) @ self.w_axon
            v = (v + contrib).astype(np.int32)

        self.v = v
        self.step_num += 1
        return spikes
