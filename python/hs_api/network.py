"""CRI_network — the user-facing network object (paper §5.2, Supp A.1).

Networks are defined by three data structures:

* ``axons``   — dict: axon key -> list of (postsynaptic neuron key, weight)
* ``neurons`` — dict: neuron key -> (list of outgoing synapses, neuron model)
* ``outputs`` — list of neuron keys whose spiking is monitored

`step(inputs)` runs one timestep on the local numpy simulator (Fig 8).
`export_hsn(path)` serialises the flattened network to the binary `.hsn`
format that the Rust coordinator compiles into the HBM routing table
(rust/src/model_fmt/hsn.rs mirrors the reader).
"""

from __future__ import annotations

import struct

import numpy as np

from .neuron_models import ANN_neuron, LIF_neuron
from .simulator import NumpySimulator

HSN_MAGIC = b"HSNET1\x00\x00"
WEIGHT_MIN, WEIGHT_MAX = -(2**15), 2**15 - 1  # int16 synapses


class CRI_network:
    """A HiAER-Spike network with the hs_api interaction surface."""

    def __init__(self, axons: dict, neurons: dict, outputs: list, base_seed: int = 0):
        self.axon_keys = list(axons.keys())
        self.neuron_keys = list(neurons.keys())
        self.axon_index = {k: i for i, k in enumerate(self.axon_keys)}
        self.neuron_index = {k: i for i, k in enumerate(self.neuron_keys)}
        if len(self.axon_index) != len(self.axon_keys):
            raise ValueError("duplicate axon keys")
        if len(self.neuron_index) != len(self.neuron_keys):
            raise ValueError("duplicate neuron keys")

        n, a = len(self.neuron_keys), len(self.axon_keys)
        self.outputs = list(outputs)
        for k in self.outputs:
            if k not in self.neuron_index:
                raise ValueError(f"output {k!r} is not a neuron")

        # per-neuron model parameter arrays
        theta = np.zeros(n, np.int32)
        nu = np.zeros(n, np.int32)
        lam = np.zeros(n, np.int32)
        flags = np.zeros(n, np.int32)
        self.models = []
        for i, k in enumerate(self.neuron_keys):
            syns, model = neurons[k]
            if not isinstance(model, (LIF_neuron, ANN_neuron)):
                raise TypeError(f"neuron {k!r}: bad model {model!r}")
            theta[i] = model.theta
            nu[i] = model.nu
            lam[i] = model.lam
            flags[i] = model.flags
            self.models.append(model)

        # adjacency (kept sparse for export, densified for simulation)
        self.neuron_syns: list[list[tuple[int, int]]] = []
        for k in self.neuron_keys:
            syns, _ = neurons[k]
            self.neuron_syns.append([self._syn(k, s) for s in syns])
        self.axon_syns: list[list[tuple[int, int]]] = []
        for k in self.axon_keys:
            self.axon_syns.append([self._syn(k, s) for s in axons[k]])

        w_neuron = np.zeros((n, n), np.int32)
        for i, syns in enumerate(self.neuron_syns):
            for j, w in syns:
                w_neuron[i, j] += w
        w_axon = np.zeros((a, n), np.int32)
        for i, syns in enumerate(self.axon_syns):
            for j, w in syns:
                w_axon[i, j] += w

        self.sim = NumpySimulator(w_axon, w_neuron, theta, nu, lam, flags, base_seed)
        self._out_idx = np.array([self.neuron_index[k] for k in self.outputs], np.int64)

    def _syn(self, src, s):
        post, w = s
        if post not in self.neuron_index:
            raise ValueError(f"synapse {src!r}->{post!r}: unknown postsynaptic neuron")
        w = int(w)
        if not (WEIGHT_MIN <= w <= WEIGHT_MAX):
            raise ValueError(f"synapse {src!r}->{post!r}: weight {w} outside int16")
        return (self.neuron_index[post], w)

    # ------------------------------------------------------------------ API

    def step(self, inputs: list, membranePotential: bool = False):
        """Run one timestep; `inputs` is a list of axon keys to activate.

        Returns the list of output-neuron keys that spiked (and, when
        membranePotential=True, the list of (key, V) for every neuron).
        """
        axon_in = np.zeros(len(self.axon_keys), np.int32)
        for k in inputs:
            axon_in[self.axon_index[k]] = 1
        spikes = self.sim.step(axon_in)
        fired = [k for k in self.outputs if spikes[self.neuron_index[k]]]
        if membranePotential:
            pots = [(k, int(self.sim.v[i])) for i, k in enumerate(self.neuron_keys)]
            return fired, pots
        return fired

    def reset(self):
        self.sim.reset()

    def read_synapse(self, pre, post) -> int:
        syns = self._syns_of(pre)
        j = self.neuron_index[post]
        for t, w in syns:
            if t == j:
                return w
        raise KeyError(f"no synapse {pre!r} -> {post!r}")

    def write_synapse(self, pre, post, weight: int) -> None:
        if not (WEIGHT_MIN <= int(weight) <= WEIGHT_MAX):
            raise ValueError(f"weight {weight} outside int16")
        syns = self._syns_of(pre)
        j = self.neuron_index[post]
        for i, (t, w) in enumerate(syns):
            if t == j:
                delta = int(weight) - w
                syns[i] = (t, int(weight))
                if pre in self.neuron_index:
                    self.sim.w_neuron[self.neuron_index[pre], j] += delta
                else:
                    self.sim.w_axon[self.axon_index[pre], j] += delta
                return
        raise KeyError(f"no synapse {pre!r} -> {post!r}")

    def read_membrane(self, *keys) -> list[int]:
        return [int(self.sim.v[self.neuron_index[k]]) for k in keys]

    def _syns_of(self, pre):
        if pre in self.neuron_index:
            return self.neuron_syns[self.neuron_index[pre]]
        if pre in self.axon_index:
            return self.axon_syns[self.axon_index[pre]]
        raise KeyError(f"unknown presynaptic key {pre!r}")

    # --------------------------------------------------------------- export

    def export_hsn(self, path: str, base_seed: int | None = None) -> None:
        """Write the flattened network in the binary .hsn format."""
        n, a = len(self.neuron_keys), len(self.axon_keys)
        out = bytearray()
        out += HSN_MAGIC
        out += struct.pack(
            "<IIIIi", a, n, len(self.outputs), 0,
            int(base_seed if base_seed is not None else self.sim.base_seed),
        )
        sim = self.sim
        params = np.stack(
            [sim.theta, sim.nu, sim.lam, sim.flags], axis=1
        ).astype("<i4")
        out += params.tobytes()

        def pack_adj(adj):
            buf = bytearray()
            for syns in adj:
                buf += struct.pack("<I", len(syns))
                if syns:
                    arr = np.array(syns, np.int64)
                    rec = np.zeros(len(syns), dtype=[("t", "<u4"), ("w", "<i2")])
                    rec["t"] = arr[:, 0]
                    rec["w"] = arr[:, 1]
                    buf += rec.tobytes()
            return bytes(buf)

        out += pack_adj(self.neuron_syns)
        out += pack_adj(self.axon_syns)
        out += np.asarray(self._out_idx, "<u4").tobytes()
        with open(path, "wb") as f:
            f.write(bytes(out))
