"""CRI_network — the user-facing network object (paper §5.2, Supp A.1).

Networks are defined by three data structures:

* ``axons``   — dict: axon key -> list of (postsynaptic neuron key, weight)
* ``neurons`` — dict: neuron key -> (list of outgoing synapses, neuron model)
* ``outputs`` — list of neuron keys whose spiking is monitored

Execution is delegated to a pluggable **backend session**
(:mod:`hs_api.backend`), selected per network instance:

    net = CRI_network(axons, neurons, outputs)                  # local numpy
    net = CRI_network(axons, neurons, outputs, backend="rust")  # Rust engine

``backend="local"`` is the in-process Fig-8 numpy simulator;
``backend="rust"`` exports the network as ``.hsn`` and drives a
``hiaer-spike serve-session`` subprocess over the JSON-lines session
protocol — same ``step`` results, bit-for-bit, with zero other code
changes. A constructed :class:`~hs_api.backend.SimBackend` instance is
also accepted (e.g. ``RustSessionBackend(server_args=["--backend",
"pool"])`` to reach the other Rust engines).

`step(inputs)` runs one timestep; `step_many(schedule)` runs a whole
stimulus schedule in one backend round trip. `export_hsn(path)`
serialises the flattened network to the binary `.hsn` format that the
Rust coordinator compiles into the HBM routing table — by default the
v2 sectioned layout the Rust side mmaps and loads zero-copy
(``version=1`` keeps the legacy streamed format).
rust/src/model_fmt/hsn.rs mirrors the readers and is the format spec;
synapses are written in canonical target-sorted order so both languages
produce identical bytes.
"""

from __future__ import annotations

import struct

import numpy as np

from .backend import make_backend
from .neuron_models import ANN_neuron, LIF_neuron

HSN_MAGIC = b"HSNET1\x00\x00"
HSN_MAGIC_V2 = b"HSNET2\x00\x00"
WEIGHT_MIN, WEIGHT_MAX = -(2**15), 2**15 - 1  # int16 synapses


class CRI_network:
    """A HiAER-Spike network with the hs_api interaction surface."""

    def __init__(self, axons: dict, neurons: dict, outputs: list,
                 base_seed: int = 0, backend="local"):
        self.axon_keys = list(axons.keys())
        self.neuron_keys = list(neurons.keys())
        self.axon_index = {k: i for i, k in enumerate(self.axon_keys)}
        self.neuron_index = {k: i for i, k in enumerate(self.neuron_keys)}
        if len(self.axon_index) != len(self.axon_keys):
            raise ValueError("duplicate axon keys")
        if len(self.neuron_index) != len(self.neuron_keys):
            raise ValueError("duplicate neuron keys")

        n = len(self.neuron_keys)
        self.outputs = list(outputs)
        for k in self.outputs:
            if k not in self.neuron_index:
                raise ValueError(f"output {k!r} is not a neuron")
        self.base_seed = int(base_seed)

        # per-neuron model parameter arrays
        self.theta = np.zeros(n, np.int32)
        self.nu = np.zeros(n, np.int32)
        self.lam = np.zeros(n, np.int32)
        self.flags = np.zeros(n, np.int32)
        self.models = []
        for i, k in enumerate(self.neuron_keys):
            syns, model = neurons[k]
            if not isinstance(model, (LIF_neuron, ANN_neuron)):
                raise TypeError(f"neuron {k!r}: bad model {model!r}")
            self.theta[i] = model.theta
            self.nu[i] = model.nu
            self.lam[i] = model.lam
            self.flags[i] = model.flags
            self.models.append(model)

        # sparse adjacency: the canonical network definition (backends
        # densify or export as needed)
        self.neuron_syns: list[list[tuple[int, int]]] = []
        for k in self.neuron_keys:
            syns, _ = neurons[k]
            self.neuron_syns.append([self._syn(k, s) for s in syns])
        self.axon_syns: list[list[tuple[int, int]]] = []
        for k in self.axon_keys:
            self.axon_syns.append([self._syn(k, s) for s in axons[k]])

        self.out_idx = np.array(
            [self.neuron_index[k] for k in self.outputs], np.int64
        )

        self._backend = make_backend(backend)
        self._backend.configure(self)

    def _syn(self, src, s):
        post, w = s
        if post not in self.neuron_index:
            raise ValueError(f"synapse {src!r}->{post!r}: unknown postsynaptic neuron")
        w = int(w)
        if not (WEIGHT_MIN <= w <= WEIGHT_MAX):
            raise ValueError(f"synapse {src!r}->{post!r}: weight {w} outside int16")
        return (self.neuron_index[post], w)

    # ------------------------------------------------------------ accessors

    @property
    def n_neurons(self) -> int:
        return len(self.neuron_keys)

    @property
    def n_axons(self) -> int:
        return len(self.axon_keys)

    @property
    def backend(self):
        """The live execution backend session."""
        return self._backend

    @property
    def sim(self):
        """The in-process :class:`NumpySimulator` when running on the
        local backend (``None`` on session backends)."""
        return getattr(self._backend, "sim", None)

    # ------------------------------------------------------------------ API

    def step(self, inputs: list, membranePotential: bool = False):
        """Run one timestep; `inputs` is a list of axon keys to activate.

        Returns the list of output-neuron keys that spiked (and, when
        membranePotential=True, the list of (key, V) for every neuron).
        """
        fired_idx = self._backend.step([self.axon_index[k] for k in inputs])
        fired = self._fired_keys(fired_idx)
        if membranePotential:
            return fired, self._all_potentials()
        return fired

    def step_many(self, schedule: list, membranePotential: bool = False):
        """Run one timestep per entry of `schedule` (each entry a list of
        axon keys) in a **single backend round trip** — on the Rust
        session backend the whole stimulus batch crosses the wire once.

        Returns one fired-output-keys list per step (and, when
        membranePotential=True, the final (key, V) list)."""
        batch = [[self.axon_index[k] for k in row] for row in schedule]
        fired = [self._fired_keys(idx) for idx in self._backend.step_many(batch)]
        if membranePotential:
            return fired, self._all_potentials()
        return fired

    def _fired_keys(self, fired_idx):
        fired_set = set(fired_idx)
        return [k for k in self.outputs if self.neuron_index[k] in fired_set]

    def _all_potentials(self):
        v = self._backend.read_membrane(list(range(self.n_neurons)))
        return list(zip(self.neuron_keys, (int(x) for x in v)))

    def reset(self):
        self._backend.reset()

    def cost(self):
        """Hardware cost counters since the last reset (session backends;
        ``None`` on the local software simulator)."""
        return self._backend.cost()

    def close(self):
        """Tear down the backend session (subprocess, temp files).
        Idempotent; also available via ``with CRI_network(...) as net:``."""
        self._backend.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def read_synapse(self, pre, post) -> int:
        syns = self._syns_of(pre)
        j = self.neuron_index[post]
        for t, w in syns:
            if t == j:
                return w
        raise KeyError(f"no synapse {pre!r} -> {post!r}")

    def write_synapse(self, pre, post, weight: int) -> None:
        """Update one synapse weight in the definition **and** the live
        backend session. On the local backend the dense matrices are
        patched in place; the Rust session backend re-exports and
        reconfigures (a hardware routing-table reload — membranes reset).
        """
        if not (WEIGHT_MIN <= int(weight) <= WEIGHT_MAX):
            raise ValueError(f"weight {weight} outside int16")
        syns = self._syns_of(pre)
        j = self.neuron_index[post]
        for i, (t, w) in enumerate(syns):
            if t == j:
                syns[i] = (t, int(weight))
                pre_is_axon = pre not in self.neuron_index
                pre_idx = self.axon_index[pre] if pre_is_axon else self.neuron_index[pre]
                try:
                    self._backend.write_synapse(pre_is_axon, pre_idx, j, w, int(weight))
                except Exception:
                    # keep definition and live session in lockstep: a
                    # failed propagation must not leave read_synapse
                    # reporting a weight the session never loaded
                    syns[i] = (t, w)
                    raise
                return
        raise KeyError(f"no synapse {pre!r} -> {post!r}")

    def read_membrane(self, *keys) -> list[int]:
        return self._backend.read_membrane([self.neuron_index[k] for k in keys])

    def _syns_of(self, pre):
        if pre in self.neuron_index:
            return self.neuron_syns[self.neuron_index[pre]]
        if pre in self.axon_index:
            return self.axon_syns[self.axon_index[pre]]
        raise KeyError(f"unknown presynaptic key {pre!r}")

    # --------------------------------------------------------------- export

    def export_hsn(self, path: str, base_seed: int | None = None,
                   version: int = 2) -> None:
        """Write the flattened network in the binary .hsn format.

        ``version=2`` (the default) emits the sectioned, 8-byte-aligned
        mmap-able layout the Rust side loads zero-copy
        (rust/src/model_fmt/hsn.rs module docs are the spec);
        ``version=1`` emits the legacy streamed format. Per-source
        synapse lists are written in canonical target-sorted order
        (stable, duplicates keep insertion order) — the same form
        `rust/src/snn` normalises to, so export -> Rust load -> Rust
        write reproduces identical bytes (pinned by the golden blobs in
        testdata/)."""
        seed = int(base_seed if base_seed is not None else self.base_seed)
        if version == 2:
            blob = self._hsn_v2_bytes(seed)
        elif version == 1:
            blob = self._hsn_v1_bytes(seed)
        else:
            raise ValueError(f"unknown .hsn version {version!r} (options: 1, 2)")
        with open(path, "wb") as f:
            f.write(blob)

    def _params_i4(self) -> np.ndarray:
        return np.stack(
            [self.theta, self.nu, self.lam, self.flags], axis=1
        ).astype("<i4")

    def _flat_csr(self):
        """Flatten the adjacency into canonical CSR arrays: per-source
        regions target-sorted (stable), neuron regions first, then axon
        regions continuing the same offset sequence."""
        targets: list[int] = []
        weights: list[int] = []
        neuron_off = [0]
        for syns in self.neuron_syns:
            for t, w in sorted(syns, key=lambda s: s[0]):
                targets.append(t)
                weights.append(w)
            neuron_off.append(len(targets))
        axon_off = [len(targets)]
        for syns in self.axon_syns:
            for t, w in sorted(syns, key=lambda s: s[0]):
                targets.append(t)
                weights.append(w)
            axon_off.append(len(targets))
        return neuron_off, axon_off, targets, weights

    def _hsn_v2_bytes(self, seed: int) -> bytes:
        neuron_off, axon_off, targets, weights = self._flat_csr()
        sections = [
            (1, 0, self._params_i4().tobytes()),                   # PARAMS
            (2, 0, np.asarray(neuron_off, "<u4").tobytes()),       # NEURON_OFF
            (3, 0, np.asarray(axon_off, "<u4").tobytes()),         # AXON_OFF
            (4, 0, np.asarray(targets, "<u4").tobytes()),          # SYN_TARGETS
            (5, 0, np.asarray(weights, "<i2").tobytes()),          # SYN_WEIGHTS
            (6, 0, np.asarray(self.out_idx, "<u4").tobytes()),     # OUTPUTS
        ]
        out = bytearray()
        out += HSN_MAGIC_V2
        out += struct.pack(
            "<IIIIiI", self.n_axons, self.n_neurons, len(self.outputs),
            len(sections), seed, 0,
        )
        # TOC: offsets assigned section-by-section with 8-byte alignment
        off = len(out) + 24 * len(sections)
        for sid, aux, payload in sections:
            off = (off + 7) & ~7
            out += struct.pack("<IIQQ", sid, aux, off, len(payload))
            off += len(payload)
        for _, _, payload in sections:
            out += b"\x00" * (-len(out) % 8)
            out += payload
        return bytes(out)

    def _hsn_v1_bytes(self, seed: int) -> bytes:
        n, a = self.n_neurons, self.n_axons
        out = bytearray()
        out += HSN_MAGIC
        out += struct.pack("<IIIIi", a, n, len(self.outputs), 0, seed)
        out += self._params_i4().tobytes()

        def pack_adj(adj):
            buf = bytearray()
            for syns in adj:
                buf += struct.pack("<I", len(syns))
                if syns:
                    ordered = sorted(syns, key=lambda s: s[0])
                    arr = np.array(ordered, np.int64)
                    rec = np.zeros(len(syns), dtype=[("t", "<u4"), ("w", "<i2")])
                    rec["t"] = arr[:, 0]
                    rec["w"] = arr[:, 1]
                    buf += rec.tobytes()
            return bytes(buf)

        out += pack_adj(self.neuron_syns)
        out += pack_adj(self.axon_syns)
        out += np.asarray(self.out_idx, "<u4").tobytes()
        return bytes(out)
