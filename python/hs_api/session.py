"""JSON-lines session protocol client — the Python half of the wire
format defined in ``rust/src/sim/session.rs``.

One JSON object per line in each direction; the server answers every
request with exactly one response line, in order, and emits a ``hello``
greeting (protocol version + backend) before the first request. Failed
requests carry ``ok: false`` plus a stable ``code`` which
:func:`hs_api.exceptions.error_from_code` maps to a typed exception.

The transport is pluggable: :class:`SubprocessTransport` speaks to a
spawned ``hiaer-spike serve-session`` process; :class:`TcpTransport`
connects to a shared ``hiaer-spike serve --listen`` server; tests
inject fakes with the same three methods (``send_line`` / ``recv_line``
/ ``close``) — plus ``send_bytes`` / ``recv_exact`` when the binary
wire is in play.

**Binary wire (wire v2).** ``SessionClient(transport, wire="binary")``
asks the server — in the ``configure`` request — to carry ``step_many``
stimulus and spikes as length-prefixed binary frames instead of JSON
lines: no per-spike integer formatting/parsing on either side. The
server echoes ``"wire": "binary"`` in the configure response; an old
server silently ignores the field, which the client detects (missing
echo) and reports as
:class:`~hs_api.exceptions.HsWireNegotiationError`. Everything except
``step_many`` — and every error, on either wire — stays line-delimited
JSON, so the binary wire is bit-identical by construction: same
requests, same spike trains, different encoding.
"""

from __future__ import annotations

import json
import os
import shutil
import socket
import struct
import subprocess
import time

from .exceptions import (
    HsBackendUnavailable,
    HsProtocolError,
    HsStimulusError,
    HsWireNegotiationError,
    error_from_code,
)

PROTOCOL_VERSION = 1

#: Binary wire-v2 framing (rust/src/sim/frames.rs): a frame is
#: ``0x00 sentinel | u32-LE length | u8 kind | payload`` where the
#: length counts the kind byte plus the payload. JSON lines never start
#: with NUL, so one peeked byte routes each direction of the stream.
WIRE_SENTINEL = b"\x00"
FRAME_STIM = 0x10  # client -> server: u32 n_steps, n x {u32 n, n x u32 axon_id}
FRAME_SPIKES = 0x90  # server -> client: u64 fired_total, u32 n_steps, rows

#: Server-side cap on steps per `step_many` request
#: (rust/src/sim/session.rs MAX_BATCH_STEPS); the client transparently
#: splits longer schedules into compliant requests.
MAX_BATCH_STEPS = 65_536

#: Environment variable overriding server-binary discovery.
HS_BIN_ENV = "HS_BIN"


def find_server_binary() -> str | None:
    """Locate the ``hiaer-spike`` binary: ``$HS_BIN``, the workspace
    target dirs (release then debug), then ``$PATH``. Returns ``None``
    when nothing is found (callers decide whether that is fatal)."""
    env = os.environ.get(HS_BIN_ENV)
    if env:
        return env if os.path.isfile(env) else None
    repo = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    candidates = [
        os.path.join(repo, "rust", "target", "release", "hiaer-spike"),
        os.path.join(repo, "rust", "target", "debug", "hiaer-spike"),
        os.path.join(repo, "target", "release", "hiaer-spike"),
        os.path.join(repo, "target", "debug", "hiaer-spike"),
    ]
    for c in candidates:
        if os.path.isfile(c) and os.access(c, os.X_OK):
            return c
    return shutil.which("hiaer-spike")


class SubprocessTransport:
    """Line + frame transport over a spawned ``hiaer-spike
    serve-session`` subprocess. The pipes are byte streams (binary
    frames and JSON lines share one stdout), but the line API stays
    ``str``-in/``str``-out."""

    def __init__(self, binary: str, extra_args: list[str] | None = None):
        argv = [binary, "serve-session", *(extra_args or [])]
        try:
            self.proc = subprocess.Popen(
                argv,
                stdin=subprocess.PIPE,
                stdout=subprocess.PIPE,
                stderr=subprocess.PIPE,
            )
        except OSError as e:
            raise HsBackendUnavailable(
                f"could not launch {argv[0]!r}: {e}", code="backend_unavailable"
            ) from e

    def send_line(self, line: str) -> None:
        self.send_bytes(line.encode("utf-8") + b"\n")

    def send_bytes(self, data: bytes) -> None:
        try:
            self.proc.stdin.write(data)
            self.proc.stdin.flush()
        except (BrokenPipeError, ValueError) as e:
            raise HsProtocolError(f"server pipe closed: {e}", code="closed") from e

    def recv_line(self) -> str:
        line = self.proc.stdout.readline()
        if not line:
            # include the server's dying words (e.g. a listed-options
            # flag error) instead of an opaque "closed"
            detail = ""
            try:
                err = self.proc.stderr.read() if self.proc.stderr else b""
                err = err.decode("utf-8", errors="replace")
                if err.strip():
                    detail = f" (server stderr: {err.strip()[-500:]})"
            except (OSError, ValueError):
                pass
            raise HsProtocolError(
                f"server closed the connection{detail}", code="closed"
            )
        return line.decode("utf-8").rstrip("\n")

    def recv_exact(self, n: int) -> bytes:
        """Exactly ``n`` bytes from the server, or a typed error on EOF
        mid-read (a truncated frame is never silently padded)."""
        chunks = []
        remaining = n
        while remaining > 0:
            chunk = self.proc.stdout.read(remaining)
            if not chunk:
                raise HsProtocolError(
                    f"server closed mid-frame ({n - remaining}/{n} bytes)", code="closed"
                )
            chunks.append(chunk)
            remaining -= len(chunk)
        return b"".join(chunks)

    def close(self) -> None:
        for pipe in (self.proc.stdin, self.proc.stdout, self.proc.stderr):
            try:
                if pipe and not pipe.closed:
                    pipe.close()
            except OSError:
                pass
        try:
            self.proc.wait(timeout=5)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait()


def _parse_address(address: str | tuple) -> tuple[str, int]:
    """``"host:port"`` (or a ready ``(host, port)`` tuple) -> tuple.
    IPv6 literals use the usual bracket form ``[::1]:9000``."""
    if isinstance(address, tuple):
        host, port = address
        return str(host), int(port)
    host, sep, port = address.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(
            f"bad server address {address!r} (expected 'host:port', e.g. '127.0.0.1:9000')"
        )
    return host.strip("[]"), int(port)


class TcpTransport:
    """Line transport to a shared ``hiaer-spike serve --listen`` server.

    Connecting retries with exponential backoff (the server may still be
    binding when a fleet comes up); exhausting the retries raises
    :class:`~hs_api.exceptions.HsBackendUnavailable`. After connecting
    it is the same strict one-line-per-request/response wire as the
    subprocess transport — the server greets with ``hello`` (or one
    ``server_busy`` line when it cannot admit the session).
    """

    def __init__(self, address: str | tuple, connect_retries: int = 5,
                 retry_backoff_s: float = 0.1, timeout_s: float | None = None):
        host, port = _parse_address(address)
        self._sock = None
        last_err: OSError | None = None
        for attempt in range(max(1, int(connect_retries))):
            if attempt:
                time.sleep(retry_backoff_s * (2 ** (attempt - 1)))
            try:
                self._sock = socket.create_connection((host, port), timeout=10.0)
                break
            except OSError as e:
                last_err = e
        if self._sock is None:
            raise HsBackendUnavailable(
                f"could not connect to hiaer-spike server at {host}:{port} "
                f"after {max(1, int(connect_retries))} attempt(s): {last_err}",
                code="backend_unavailable",
            )
        self._sock.settimeout(timeout_s)  # None = block indefinitely
        # byte-mode file objects: binary frames and JSON lines share the
        # one stream, so decoding happens per-line, not per-stream
        self._rfile = self._sock.makefile("rb")
        self._wfile = self._sock.makefile("wb")

    def send_line(self, line: str) -> None:
        self.send_bytes(line.encode("utf-8") + b"\n")

    def send_bytes(self, data: bytes) -> None:
        try:
            self._wfile.write(data)
            self._wfile.flush()
        except (OSError, ValueError) as e:
            raise HsProtocolError(f"server connection closed: {e}", code="closed") from e

    def recv_line(self) -> str:
        try:
            line = self._rfile.readline()
        except socket.timeout as e:
            raise HsProtocolError(
                "timed out waiting for a server response line", code="closed"
            ) from e
        except (OSError, ValueError) as e:
            raise HsProtocolError(f"server connection closed: {e}", code="closed") from e
        if not line:
            raise HsProtocolError("server closed the connection", code="closed")
        return line.decode("utf-8").rstrip("\n")

    def recv_exact(self, n: int) -> bytes:
        """Exactly ``n`` bytes, or a typed error on timeout/EOF mid-read."""
        chunks = []
        remaining = n
        while remaining > 0:
            try:
                chunk = self._rfile.read(remaining)
            except socket.timeout as e:
                raise HsProtocolError(
                    "timed out waiting for server frame bytes", code="closed"
                ) from e
            except (OSError, ValueError) as e:
                raise HsProtocolError(
                    f"server connection closed: {e}", code="closed"
                ) from e
            if not chunk:
                raise HsProtocolError(
                    f"server closed mid-frame ({n - remaining}/{n} bytes)", code="closed"
                )
            chunks.append(chunk)
            remaining -= len(chunk)
        return b"".join(chunks)

    def close(self) -> None:
        for f in (self._wfile, self._rfile):
            try:
                f.close()
            except OSError:
                pass
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass  # peer already gone
        try:
            self._sock.close()
        except OSError:
            pass


def _pack_stim_frame(rows: list[list[int]]) -> bytes:
    """One complete STIM wire frame (sentinel + length + kind +
    payload) for a validated stimulus batch — ``struct``-packed, no
    per-id string formatting."""
    parts = [struct.pack("<I", len(rows))]
    for row in rows:
        parts.append(struct.pack("<I", len(row)))
        if row:
            parts.append(struct.pack(f"<{len(row)}I", *row))
    payload = b"".join(parts)
    return (
        WIRE_SENTINEL
        + struct.pack("<I", len(payload) + 1)
        + bytes([FRAME_STIM])
        + payload
    )


def _unpack_spikes_payload(payload: bytes) -> tuple[list[list[int]], int]:
    """Decode a SPIKES payload to (per-step output-id rows,
    fired_total); trailing or missing bytes are a protocol error."""
    if len(payload) < 12:
        raise HsProtocolError(f"SPIKES payload truncated ({len(payload)} bytes)")
    fired_total, n_steps = struct.unpack_from("<QI", payload, 0)
    off = 12
    rows: list[list[int]] = []
    for _ in range(n_steps):
        if off + 4 > len(payload):
            raise HsProtocolError("SPIKES payload truncated mid-row")
        (n,) = struct.unpack_from("<I", payload, off)
        off += 4
        if off + 4 * n > len(payload):
            raise HsProtocolError("SPIKES payload truncated mid-row")
        rows.append(list(struct.unpack_from(f"<{n}I", payload, off)))
        off += 4 * n
    if off != len(payload):
        raise HsProtocolError(
            f"SPIKES payload has {len(payload) - off} trailing byte(s)"
        )
    return rows, fired_total


class SessionClient:
    """Synchronous request/response client for one protocol session.

    ``transport`` needs ``send_line`` / ``recv_line`` / ``close``. On
    construction the client consumes the server's ``hello`` greeting and
    checks the protocol version (disable with ``expect_hello=False`` for
    transports that do not greet).

    ``wire="binary"`` requests the binary stimulus/spike wire at every
    ``configure`` (the transport additionally needs ``send_bytes`` /
    ``recv_exact``). Negotiation failure — an old server that does not
    echo the ``wire`` field — raises
    :class:`~hs_api.exceptions.HsWireNegotiationError` from
    :meth:`configure`."""

    def __init__(self, transport, expect_hello: bool = True, wire: str = "json"):
        if wire not in ("json", "binary"):
            raise ValueError(f"wire must be 'json' or 'binary', not {wire!r}")
        self.transport = transport
        self.server_backend: str | None = None
        self.wire = wire
        #: True only after the server acknowledged ``"wire":"binary"``
        #: for the current configure epoch.
        self._wire_binary = False
        #: axon count of the configured net (from the configure
        #: response) — lets the client validate whole schedules before
        #: sending anything, so multi-chunk ``step_many`` stays atomic.
        self._n_axons: int | None = None
        if expect_hello:
            hello = self._recv()
            if not hello.get("ok") and hello.get("code"):
                # a shared server may answer one typed error line instead
                # of hello (e.g. server_busy while at capacity/draining)
                raise error_from_code(
                    hello["code"], hello.get("error", f"server refused session: {hello!r}")
                )
            if hello.get("op") != "hello" or not hello.get("ok"):
                raise HsProtocolError(f"expected hello greeting, got {hello!r}")
            if hello.get("protocol") != PROTOCOL_VERSION:
                raise HsProtocolError(
                    f"protocol version mismatch: server speaks "
                    f"{hello.get('protocol')!r}, client speaks {PROTOCOL_VERSION}"
                )
            self.server_backend = hello.get("backend")

    # ------------------------------------------------------------- plumbing

    def _recv(self) -> dict:
        line = self.transport.recv_line()
        try:
            resp = json.loads(line)
        except ValueError as e:
            raise HsProtocolError(f"unparseable server line {line!r}: {e}") from e
        if not isinstance(resp, dict):
            raise HsProtocolError(f"server line is not an object: {line!r}")
        return resp

    def request(self, op: str, **fields) -> dict:
        """Send one request, block for its response; raise the typed
        exception for ``ok: false`` responses."""
        payload = {"op": op, **fields}
        self.transport.send_line(json.dumps(payload, separators=(",", ":")))
        resp = self._recv()
        if not resp.get("ok"):
            raise error_from_code(
                resp.get("code", "engine"), resp.get("error", f"{op} failed: {resp!r}")
            )
        return resp

    # ------------------------------------------------------------------ ops

    def configure(self, net_path: str, seed: int | None = None,
                  workers: int | None = None,
                  shards: int | None = None,
                  learning: dict | None = None) -> dict:
        """Build/replace the server-side simulator. ``workers`` sets the
        worker-thread count of the pooled Rust backends (>= 1; the
        server rejects 0 with a ``config`` error). Spike trains are
        worker-count-invariant — this only tunes throughput.

        ``shards`` selects the multi-process sharded backend with that
        many worker subprocesses (>= 1, at most the server topology's
        core count; out-of-range values are rejected with a ``config``
        error). Spike trains are shard-count-invariant too — the
        server's cross-shard merge is deterministic.

        ``learning`` switches on pair-based STDP for this session: a
        dict with any of the integer keys ``a_plus``, ``a_minus``,
        ``tau_pre``, ``tau_post``, ``w_min``, ``w_max`` (server
        defaults fill the rest). Mistyped fields are rejected with
        ``malformed_request``, invalid combinations with ``config``.

        The response dict includes the server's cold-start breakdown:
        ``load_ms`` (network load — mmap + validate for ``.hsn`` v2,
        full parse for v1), ``compile_ms`` (partition + HBM compile)
        and ``net_bytes`` (on-disk file size).

        With ``wire="binary"`` on the client, this request also carries
        the wire negotiation; a server that does not acknowledge it
        raises :class:`~hs_api.exceptions.HsWireNegotiationError`."""
        fields = {"net": net_path}
        if seed is not None:
            fields["seed"] = int(seed)
        if workers is not None:
            fields["workers"] = int(workers)
        if shards is not None:
            fields["shards"] = int(shards)
        if learning is not None:
            fields["learning"] = {k: int(v) for k, v in dict(learning).items()}
        if self.wire == "binary":
            fields["wire"] = "binary"
        self._wire_binary = False  # each configure re-negotiates
        resp = self.request("configure", **fields)
        if self.wire == "binary":
            if resp.get("wire") != "binary":
                raise HsWireNegotiationError(
                    "server did not acknowledge the binary wire (response "
                    f"echoed wire={resp.get('wire')!r}; old servers omit the "
                    "field entirely) — reconnect with wire='json'"
                )
            self._wire_binary = True
        self._n_axons = resp.get("axons")
        return resp

    def step(self, axons: list[int]) -> list[int]:
        """One tick; returns fired output-neuron ids (ascending)."""
        return self.request("step", axons=[int(a) for a in axons])["spikes"]

    def step_many(self, batch: list[list[int]]) -> list[list[int]]:
        """A whole stimulus batch in one round trip (split transparently
        into <= MAX_BATCH_STEPS-step requests for longer schedules, so
        schedules that run locally run over the wire too); returns the
        per-step output-spike lists.

        The whole schedule is range-checked against the configured
        net's axon count *before the first chunk is sent*, so a bad id
        anywhere — including the last chunk of a multi-chunk schedule —
        executes zero steps, matching the server's own atomic per-request
        validation. On the negotiated binary wire each chunk travels as
        one struct-packed STIM frame and comes back as a SPIKES frame."""
        rows = [[int(a) for a in row] for row in batch]
        # atomicity across chunks: the server validates each *request*
        # atomically, but once the client has split a long schedule,
        # only client-side whole-schedule validation stops chunk 1 from
        # executing when chunk 2 holds a bad id
        if self._n_axons is not None:
            for row in rows:
                for a in row:
                    if not (0 <= a < self._n_axons):
                        raise HsStimulusError(
                            f"axon id {a} out of range ({self._n_axons} axons); "
                            "no steps executed",
                            code="stimulus",
                        )
        spikes: list[list[int]] = []
        for i in range(0, len(rows), MAX_BATCH_STEPS):
            chunk = rows[i:i + MAX_BATCH_STEPS]
            if self._wire_binary:
                spikes.extend(self._step_many_binary(chunk))
            else:
                spikes.extend(self.request("step_many", batch=chunk)["spikes"])
        return spikes

    def _step_many_binary(self, rows: list[list[int]]) -> list[list[int]]:
        """One STIM frame out, one SPIKES frame (or a JSON error line)
        back. Errors are always JSON lines, on either wire."""
        self.transport.send_bytes(_pack_stim_frame(rows))
        first = self.transport.recv_exact(1)
        if first != WIRE_SENTINEL:
            # a JSON error line: the peeked byte is its first character
            line = first.decode("utf-8") + self.transport.recv_line()
            try:
                resp = json.loads(line)
            except ValueError as e:
                raise HsProtocolError(f"unparseable server line {line!r}: {e}") from e
            raise error_from_code(
                resp.get("code", "engine"),
                resp.get("error", f"step_many failed: {resp!r}"),
            )
        (frame_len,) = struct.unpack("<I", self.transport.recv_exact(4))
        if frame_len < 1:
            raise HsProtocolError(f"bad server frame length {frame_len}")
        body = self.transport.recv_exact(frame_len)
        kind, payload = body[0], body[1:]
        if kind != FRAME_SPIKES:
            raise HsProtocolError(f"expected SPIKES frame, got kind 0x{kind:02x}")
        rows_out, _fired_total = _unpack_spikes_payload(payload)
        return rows_out

    def read_membrane(self, ids: list[int]) -> list[int]:
        return self.request("read_membrane", ids=[int(i) for i in ids])["v"]

    def write_synapse(self, pre: int, post: int, weight: int,
                      pre_is_axon: bool = False) -> dict:
        """Upsert one synapse weight live, between steps. The engine
        slot is patched in place — membranes and the step counter are
        untouched (the online-learning fast path). When the in-place
        patch is structurally impossible the server compacts its edit
        journal into a fresh network and rebuilds (``compacted: True``
        in the response; membranes reset on that path only). Returns
        the response dict with ``created`` and ``compacted`` flags."""
        resp = self.request(
            "write_synapse",
            pre=int(pre), post=int(post), weight=int(weight),
            pre_is_axon=bool(pre_is_axon),
        )
        return {k: v for k, v in resp.items() if k not in ("ok", "op")}

    def reset(self) -> None:
        self.request("reset")

    def cost(self) -> dict:
        """Aggregate cost counters since the last reset (energy_uj,
        latency_us, hbm_rows, events, cycles, backend)."""
        resp = self.request("cost")
        return {k: v for k, v in resp.items() if k not in ("ok", "op")}

    def health(self) -> dict:
        """Server liveness/occupancy snapshot. Against a shared server
        this reports active sessions, queue depth and the draining flag;
        a stdio session answers for itself (protocol + configured)."""
        resp = self.request("health")
        return {k: v for k, v in resp.items() if k not in ("ok", "op")}

    def metrics(self) -> dict:
        """Lifetime counters: requests/errors/steps for a stdio session;
        a shared server adds sessions, evictions by cause, queue depth
        and per-phase step rates."""
        resp = self.request("metrics")
        return {k: v for k, v in resp.items() if k not in ("ok", "op")}

    def shutdown(self) -> None:
        self.request("shutdown")

    def close(self) -> None:
        """Best-effort shutdown + transport teardown (idempotent)."""
        try:
            self.shutdown()
        except HsProtocolError:
            pass  # pipe already gone
        self.transport.close()

    def __enter__(self) -> "SessionClient":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
