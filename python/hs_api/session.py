"""JSON-lines session protocol client — the Python half of the wire
format defined in ``rust/src/sim/session.rs``.

One JSON object per line in each direction; the server answers every
request with exactly one response line, in order, and emits a ``hello``
greeting (protocol version + backend) before the first request. Failed
requests carry ``ok: false`` plus a stable ``code`` which
:func:`hs_api.exceptions.error_from_code` maps to a typed exception.

The transport is pluggable: :class:`SubprocessTransport` speaks to a
spawned ``hiaer-spike serve-session`` process; :class:`TcpTransport`
connects to a shared ``hiaer-spike serve --listen`` server; tests
inject fakes with the same three methods (``send_line`` / ``recv_line``
/ ``close``).
"""

from __future__ import annotations

import json
import os
import shutil
import socket
import subprocess
import time

from .exceptions import HsBackendUnavailable, HsProtocolError, error_from_code

PROTOCOL_VERSION = 1

#: Server-side cap on steps per `step_many` request
#: (rust/src/sim/session.rs MAX_BATCH_STEPS); the client transparently
#: splits longer schedules into compliant requests.
MAX_BATCH_STEPS = 65_536

#: Environment variable overriding server-binary discovery.
HS_BIN_ENV = "HS_BIN"


def find_server_binary() -> str | None:
    """Locate the ``hiaer-spike`` binary: ``$HS_BIN``, the workspace
    target dirs (release then debug), then ``$PATH``. Returns ``None``
    when nothing is found (callers decide whether that is fatal)."""
    env = os.environ.get(HS_BIN_ENV)
    if env:
        return env if os.path.isfile(env) else None
    repo = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    candidates = [
        os.path.join(repo, "rust", "target", "release", "hiaer-spike"),
        os.path.join(repo, "rust", "target", "debug", "hiaer-spike"),
        os.path.join(repo, "target", "release", "hiaer-spike"),
        os.path.join(repo, "target", "debug", "hiaer-spike"),
    ]
    for c in candidates:
        if os.path.isfile(c) and os.access(c, os.X_OK):
            return c
    return shutil.which("hiaer-spike")


class SubprocessTransport:
    """Line transport over a spawned ``hiaer-spike serve-session``
    subprocess (stdin/stdout pipes, line-buffered text mode)."""

    def __init__(self, binary: str, extra_args: list[str] | None = None):
        argv = [binary, "serve-session", *(extra_args or [])]
        try:
            self.proc = subprocess.Popen(
                argv,
                stdin=subprocess.PIPE,
                stdout=subprocess.PIPE,
                stderr=subprocess.PIPE,
                text=True,
                bufsize=1,
            )
        except OSError as e:
            raise HsBackendUnavailable(
                f"could not launch {argv[0]!r}: {e}", code="backend_unavailable"
            ) from e

    def send_line(self, line: str) -> None:
        try:
            self.proc.stdin.write(line + "\n")
            self.proc.stdin.flush()
        except (BrokenPipeError, ValueError) as e:
            raise HsProtocolError(f"server pipe closed: {e}", code="closed") from e

    def recv_line(self) -> str:
        line = self.proc.stdout.readline()
        if not line:
            # include the server's dying words (e.g. a listed-options
            # flag error) instead of an opaque "closed"
            detail = ""
            try:
                err = self.proc.stderr.read() if self.proc.stderr else ""
                if err.strip():
                    detail = f" (server stderr: {err.strip()[-500:]})"
            except (OSError, ValueError):
                pass
            raise HsProtocolError(
                f"server closed the connection{detail}", code="closed"
            )
        return line.rstrip("\n")

    def close(self) -> None:
        for pipe in (self.proc.stdin, self.proc.stdout, self.proc.stderr):
            try:
                if pipe and not pipe.closed:
                    pipe.close()
            except OSError:
                pass
        try:
            self.proc.wait(timeout=5)
        except subprocess.TimeoutExpired:
            self.proc.kill()
            self.proc.wait()


def _parse_address(address: str | tuple) -> tuple[str, int]:
    """``"host:port"`` (or a ready ``(host, port)`` tuple) -> tuple.
    IPv6 literals use the usual bracket form ``[::1]:9000``."""
    if isinstance(address, tuple):
        host, port = address
        return str(host), int(port)
    host, sep, port = address.rpartition(":")
    if not sep or not port.isdigit():
        raise ValueError(
            f"bad server address {address!r} (expected 'host:port', e.g. '127.0.0.1:9000')"
        )
    return host.strip("[]"), int(port)


class TcpTransport:
    """Line transport to a shared ``hiaer-spike serve --listen`` server.

    Connecting retries with exponential backoff (the server may still be
    binding when a fleet comes up); exhausting the retries raises
    :class:`~hs_api.exceptions.HsBackendUnavailable`. After connecting
    it is the same strict one-line-per-request/response wire as the
    subprocess transport — the server greets with ``hello`` (or one
    ``server_busy`` line when it cannot admit the session).
    """

    def __init__(self, address: str | tuple, connect_retries: int = 5,
                 retry_backoff_s: float = 0.1, timeout_s: float | None = None):
        host, port = _parse_address(address)
        self._sock = None
        last_err: OSError | None = None
        for attempt in range(max(1, int(connect_retries))):
            if attempt:
                time.sleep(retry_backoff_s * (2 ** (attempt - 1)))
            try:
                self._sock = socket.create_connection((host, port), timeout=10.0)
                break
            except OSError as e:
                last_err = e
        if self._sock is None:
            raise HsBackendUnavailable(
                f"could not connect to hiaer-spike server at {host}:{port} "
                f"after {max(1, int(connect_retries))} attempt(s): {last_err}",
                code="backend_unavailable",
            )
        self._sock.settimeout(timeout_s)  # None = block indefinitely
        self._rfile = self._sock.makefile("r", encoding="utf-8", newline="\n")
        self._wfile = self._sock.makefile("w", encoding="utf-8", newline="\n")

    def send_line(self, line: str) -> None:
        try:
            self._wfile.write(line + "\n")
            self._wfile.flush()
        except (OSError, ValueError) as e:
            raise HsProtocolError(f"server connection closed: {e}", code="closed") from e

    def recv_line(self) -> str:
        try:
            line = self._rfile.readline()
        except socket.timeout as e:
            raise HsProtocolError(
                "timed out waiting for a server response line", code="closed"
            ) from e
        except (OSError, ValueError) as e:
            raise HsProtocolError(f"server connection closed: {e}", code="closed") from e
        if not line:
            raise HsProtocolError("server closed the connection", code="closed")
        return line.rstrip("\n")

    def close(self) -> None:
        for f in (self._wfile, self._rfile):
            try:
                f.close()
            except OSError:
                pass
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass  # peer already gone
        try:
            self._sock.close()
        except OSError:
            pass


class SessionClient:
    """Synchronous request/response client for one protocol session.

    ``transport`` needs ``send_line`` / ``recv_line`` / ``close``. On
    construction the client consumes the server's ``hello`` greeting and
    checks the protocol version (disable with ``expect_hello=False`` for
    transports that do not greet)."""

    def __init__(self, transport, expect_hello: bool = True):
        self.transport = transport
        self.server_backend: str | None = None
        if expect_hello:
            hello = self._recv()
            if not hello.get("ok") and hello.get("code"):
                # a shared server may answer one typed error line instead
                # of hello (e.g. server_busy while at capacity/draining)
                raise error_from_code(
                    hello["code"], hello.get("error", f"server refused session: {hello!r}")
                )
            if hello.get("op") != "hello" or not hello.get("ok"):
                raise HsProtocolError(f"expected hello greeting, got {hello!r}")
            if hello.get("protocol") != PROTOCOL_VERSION:
                raise HsProtocolError(
                    f"protocol version mismatch: server speaks "
                    f"{hello.get('protocol')!r}, client speaks {PROTOCOL_VERSION}"
                )
            self.server_backend = hello.get("backend")

    # ------------------------------------------------------------- plumbing

    def _recv(self) -> dict:
        line = self.transport.recv_line()
        try:
            resp = json.loads(line)
        except ValueError as e:
            raise HsProtocolError(f"unparseable server line {line!r}: {e}") from e
        if not isinstance(resp, dict):
            raise HsProtocolError(f"server line is not an object: {line!r}")
        return resp

    def request(self, op: str, **fields) -> dict:
        """Send one request, block for its response; raise the typed
        exception for ``ok: false`` responses."""
        payload = {"op": op, **fields}
        self.transport.send_line(json.dumps(payload, separators=(",", ":")))
        resp = self._recv()
        if not resp.get("ok"):
            raise error_from_code(
                resp.get("code", "engine"), resp.get("error", f"{op} failed: {resp!r}")
            )
        return resp

    # ------------------------------------------------------------------ ops

    def configure(self, net_path: str, seed: int | None = None,
                  workers: int | None = None,
                  shards: int | None = None,
                  learning: dict | None = None) -> dict:
        """Build/replace the server-side simulator. ``workers`` sets the
        worker-thread count of the pooled Rust backends (>= 1; the
        server rejects 0 with a ``config`` error). Spike trains are
        worker-count-invariant — this only tunes throughput.

        ``shards`` selects the multi-process sharded backend with that
        many worker subprocesses (>= 1, at most the server topology's
        core count; out-of-range values are rejected with a ``config``
        error). Spike trains are shard-count-invariant too — the
        server's cross-shard merge is deterministic.

        ``learning`` switches on pair-based STDP for this session: a
        dict with any of the integer keys ``a_plus``, ``a_minus``,
        ``tau_pre``, ``tau_post``, ``w_min``, ``w_max`` (server
        defaults fill the rest). Mistyped fields are rejected with
        ``malformed_request``, invalid combinations with ``config``.

        The response dict includes the server's cold-start breakdown:
        ``load_ms`` (network load — mmap + validate for ``.hsn`` v2,
        full parse for v1), ``compile_ms`` (partition + HBM compile)
        and ``net_bytes`` (on-disk file size)."""
        fields = {"net": net_path}
        if seed is not None:
            fields["seed"] = int(seed)
        if workers is not None:
            fields["workers"] = int(workers)
        if shards is not None:
            fields["shards"] = int(shards)
        if learning is not None:
            fields["learning"] = {k: int(v) for k, v in dict(learning).items()}
        return self.request("configure", **fields)

    def step(self, axons: list[int]) -> list[int]:
        """One tick; returns fired output-neuron ids (ascending)."""
        return self.request("step", axons=[int(a) for a in axons])["spikes"]

    def step_many(self, batch: list[list[int]]) -> list[list[int]]:
        """A whole stimulus batch in one round trip (split transparently
        into <= MAX_BATCH_STEPS-step requests for longer schedules, so
        schedules that run locally run over the wire too); returns the
        per-step output-spike lists. Each request is validated atomically
        server-side; with multiple chunks, earlier chunks may have
        executed when a later chunk's stimulus is rejected."""
        rows = [[int(a) for a in row] for row in batch]
        spikes: list[list[int]] = []
        for i in range(0, len(rows), MAX_BATCH_STEPS):
            spikes.extend(
                self.request("step_many", batch=rows[i:i + MAX_BATCH_STEPS])["spikes"]
            )
        return spikes

    def read_membrane(self, ids: list[int]) -> list[int]:
        return self.request("read_membrane", ids=[int(i) for i in ids])["v"]

    def write_synapse(self, pre: int, post: int, weight: int,
                      pre_is_axon: bool = False) -> dict:
        """Upsert one synapse weight live, between steps. The engine
        slot is patched in place — membranes and the step counter are
        untouched (the online-learning fast path). When the in-place
        patch is structurally impossible the server compacts its edit
        journal into a fresh network and rebuilds (``compacted: True``
        in the response; membranes reset on that path only). Returns
        the response dict with ``created`` and ``compacted`` flags."""
        resp = self.request(
            "write_synapse",
            pre=int(pre), post=int(post), weight=int(weight),
            pre_is_axon=bool(pre_is_axon),
        )
        return {k: v for k, v in resp.items() if k not in ("ok", "op")}

    def reset(self) -> None:
        self.request("reset")

    def cost(self) -> dict:
        """Aggregate cost counters since the last reset (energy_uj,
        latency_us, hbm_rows, events, cycles, backend)."""
        resp = self.request("cost")
        return {k: v for k, v in resp.items() if k not in ("ok", "op")}

    def health(self) -> dict:
        """Server liveness/occupancy snapshot. Against a shared server
        this reports active sessions, queue depth and the draining flag;
        a stdio session answers for itself (protocol + configured)."""
        resp = self.request("health")
        return {k: v for k, v in resp.items() if k not in ("ok", "op")}

    def metrics(self) -> dict:
        """Lifetime counters: requests/errors/steps for a stdio session;
        a shared server adds sessions, evictions by cause, queue depth
        and per-phase step rates."""
        resp = self.request("metrics")
        return {k: v for k, v in resp.items() if k not in ("ok", "op")}

    def shutdown(self) -> None:
        self.request("shutdown")

    def close(self) -> None:
        """Best-effort shutdown + transport teardown (idempotent)."""
        try:
            self.shutdown()
        except HsProtocolError:
            pass  # pipe already gone
        self.transport.close()

    def __enter__(self) -> "SessionClient":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
