"""Pluggable execution backends for :class:`hs_api.network.CRI_network`.

A backend is a *session*: it is configured once with a network and then
drives every execution-facing call (``step`` / ``step_many`` /
``read_membrane`` / ``reset`` / ``cost``). The network object owns keys,
validation and key<->index mapping; backends only ever see global
integer ids, which is exactly the Rust ``Simulator`` facade contract —
so one network definition runs unchanged on either side of the language
boundary:

* :class:`LocalBackend` — the in-process numpy simulator (Fig 8), the
  default and the golden model.
* :class:`RustSessionBackend` — exports the network as ``.hsn``,
  launches ``hiaer-spike serve-session`` and speaks the line-delimited
  JSON protocol (``rust/src/sim/session.rs``) to any engine the Rust
  facade can build (event-driven core, chunk-parallel pool, cluster,
  XLA).

Both return **sorted global output-neuron ids** from ``step`` and are
bit-identical on the same network and seed (pinned by the golden
transcript in ``testdata/`` and ``python/tests/test_golden_hsn.py``).
"""

from __future__ import annotations

import abc
import os
import tempfile

import numpy as np

from .exceptions import HsBackendUnavailable, HsSessionError, HsStimulusError
from .session import SessionClient, SubprocessTransport, TcpTransport, find_server_binary
from .simulator import NumpySimulator


def _check_ids(ids, n: int, kind: str) -> None:
    """Shared range check: both backends raise the same
    :class:`HsStimulusError` (code ``stimulus``) for the same bad input
    — no numpy wraparound, no bare IndexError, no wire-level
    ``malformed_request`` divergence."""
    for i in ids:
        if not (0 <= int(i) < n):
            raise HsStimulusError(
                f"{kind} id {int(i)} out of range ({n} {kind}s)", code="stimulus"
            )


class SimBackend(abc.ABC):
    """One execution session behind a ``CRI_network``."""

    #: short identifier ("local", "rust", ...)
    name: str = "?"

    @abc.abstractmethod
    def configure(self, network) -> None:
        """Bind this backend to a built ``CRI_network`` (called once by
        the network's constructor; may also be re-invoked to reload
        after structural edits)."""

    @abc.abstractmethod
    def step(self, axon_ids: list[int]) -> list[int]:
        """Advance one tick with the given fired global axon ids; return
        the fired output-neuron ids, ascending."""

    def step_many(self, batch: list[list[int]]) -> list[list[int]]:
        """Advance one tick per batch entry; default is a step loop —
        session backends override to use one protocol round trip."""
        return [self.step(row) for row in batch]

    @abc.abstractmethod
    def read_membrane(self, ids: list[int]) -> list[int]:
        """Membrane potentials for global neuron ids."""

    @abc.abstractmethod
    def reset(self) -> None:
        """Restore membranes/step counter to the initial state."""

    @abc.abstractmethod
    def write_synapse(self, pre_is_axon: bool, pre: int, post: int,
                      old_weight: int, new_weight: int) -> None:
        """Propagate a synapse-weight edit into the running session."""

    def cost(self) -> dict | None:
        """Hardware cost counters since the last reset; ``None`` when the
        backend does not model hardware cost."""
        return None

    def close(self) -> None:
        """Release session resources (subprocesses, temp files)."""


class LocalBackend(SimBackend):
    """The Fig-8 numpy software simulator, densified in-process.

    Exposes the underlying :class:`NumpySimulator` as ``.sim`` (tests
    and notebooks poke at ``sim.v`` / ``sim.w_axon`` directly)."""

    name = "local"

    def __init__(self):
        self.sim: NumpySimulator | None = None
        self._out_sorted: np.ndarray | None = None

    def configure(self, network) -> None:
        n, a = network.n_neurons, network.n_axons
        w_neuron = np.zeros((n, n), np.int32)
        for i, syns in enumerate(network.neuron_syns):
            for j, w in syns:
                w_neuron[i, j] += w
        w_axon = np.zeros((a, n), np.int32)
        for i, syns in enumerate(network.axon_syns):
            for j, w in syns:
                w_axon[i, j] += w
        self.sim = NumpySimulator(
            w_axon, w_neuron, network.theta, network.nu, network.lam,
            network.flags, network.base_seed,
        )
        self._out_sorted = np.unique(network.out_idx)

    def step(self, axon_ids: list[int]) -> list[int]:
        n_axons = self.sim.w_axon.shape[0]
        _check_ids(axon_ids, n_axons, "axon")
        axon_in = np.zeros(n_axons, np.int32)
        for a in axon_ids:
            axon_in[int(a)] = 1
        spikes = self.sim.step(axon_in)
        fired = self._out_sorted[spikes[self._out_sorted] != 0]
        return [int(i) for i in fired]

    def step_many(self, batch: list[list[int]]) -> list[list[int]]:
        # mirror Simulator::step_many's atomic contract: validate the
        # whole batch before any step executes
        n_axons = self.sim.w_axon.shape[0]
        for row in batch:
            _check_ids(row, n_axons, "axon")
        return [self.step(row) for row in batch]

    def read_membrane(self, ids: list[int]) -> list[int]:
        _check_ids(ids, len(self.sim.v), "neuron")
        return [int(self.sim.v[i]) for i in ids]

    def reset(self) -> None:
        self.sim.reset()

    def write_synapse(self, pre_is_axon, pre, post, old_weight, new_weight):
        m = self.sim.w_axon if pre_is_axon else self.sim.w_neuron
        m[pre, post] += np.int32(new_weight - old_weight)


class RustSessionBackend(SimBackend):
    """Session over the Rust ``Simulator`` facade via the JSON-lines
    protocol: the network is exported to a temporary ``.hsn``, a
    ``hiaer-spike serve-session`` subprocess is launched, and every call
    becomes one request/response round trip (``step_many`` batches a
    whole schedule into a single trip).

    ``server_args`` forwards deployment flags to the server — e.g.
    ``["--backend", "pool"]`` or ``["--cores", "4"]`` — so the same
    Python network definition reaches every Rust engine. Note that the
    network's ``base_seed`` is always sent with ``configure`` and takes
    precedence over a ``--seed`` server flag: the seed belongs to the
    network definition, which is what keeps ``local`` and ``rust``
    sessions bit-identical. ``binary`` overrides discovery (default:
    ``$HS_BIN``, workspace target dirs, ``$PATH``); a missing binary
    raises :class:`~hs_api.exceptions.HsBackendUnavailable`.

    ``address="host:port"`` connects to a shared ``hiaer-spike serve
    --listen`` server over TCP instead of spawning a subprocess — same
    wire format, but quotas/deadlines/eviction apply (see the
    shared-server section of this package's README). A server at
    capacity raises :class:`~hs_api.exceptions.HsServerBusy` from the
    first call.

    ``wire="binary"`` negotiates the binary stimulus/spike wire for
    ``step_many`` (works over both transports; spike trains are
    wire-invariant — see the "Binary wire" section of the README).

    Weight edits (``write_synapse``) go over the wire as the protocol's
    ``write_synapse`` op: the server patches the compiled engine slot in
    place, so membranes and the step counter survive the edit — the
    online-learning semantics, matching :class:`LocalBackend`'s in-place
    matrix patch. Only a structurally impossible in-place patch makes
    the server compact its edit journal and rebuild (which does reset
    membranes, like a hardware routing-table reload).
    """

    name = "rust"

    def __init__(self, binary: str | None = None,
                 server_args: list[str] | None = None,
                 workers: int | None = None,
                 address: str | None = None,
                 wire: str = "json"):
        #: ``"host:port"`` of a shared ``hiaer-spike serve --listen``
        #: server. When set, the backend connects over TCP instead of
        #: spawning a subprocess (``binary``/``server_args`` are ignored
        #: — deployment flags belong to whoever runs the server).
        self._address = address
        self._binary = binary
        self._server_args = list(server_args or [])
        #: worker-thread count for the pooled Rust backends, sent with
        #: every ``configure`` (None = server default). Spike trains are
        #: worker-count-invariant; this only tunes throughput.
        self._workers = workers
        #: ``"json"`` (default) or ``"binary"``: the wire encoding for
        #: ``step_many`` stimulus/spikes. Binary skips per-spike string
        #: formatting/parsing on both sides; spike trains are
        #: wire-invariant (pinned by parity tests). Against an old
        #: server ``"binary"`` raises
        #: :class:`~hs_api.exceptions.HsWireNegotiationError` at
        #: configure time.
        self._wire = wire
        self._client: SessionClient | None = None
        self._hsn_path: str | None = None
        self._network = None

    def _launch(self) -> SessionClient:
        if self._address is not None:
            transport = TcpTransport(self._address)
            try:
                return SessionClient(transport, wire=self._wire)
            except Exception:
                transport.close()  # busy/refused greeting: free the socket
                raise
        binary = self._binary or find_server_binary()
        if binary is None:
            raise HsBackendUnavailable(
                "no `hiaer-spike` binary found (build with `cargo build "
                "--release` or point $HS_BIN at it)",
                code="backend_unavailable",
            )
        transport = SubprocessTransport(binary, self._server_args)
        try:
            return SessionClient(transport, wire=self._wire)
        except Exception:
            transport.close()  # bad/failed greeting: don't orphan the child
            raise

    def configure(self, network) -> None:
        self._network = network
        try:
            # launch first: a missing binary must fail fast without
            # leaving an exported temp .hsn behind
            if self._client is None:
                self._client = self._launch()
            if self._hsn_path is None:
                fd, self._hsn_path = tempfile.mkstemp(suffix=".hsn", prefix="hs_api_")
                os.close(fd)
            network.export_hsn(self._hsn_path)
            self._client.configure(self._hsn_path, seed=network.base_seed,
                                   workers=self._workers)
        except Exception:
            # a failed configure escapes CRI_network.__init__, so no one
            # holds this backend to close() it later — clean up the
            # subprocess and temp file here instead of leaking them
            self.close()
            raise

    def _client_or_raise(self) -> SessionClient:
        if self._client is None:
            raise HsSessionError(
                "session closed (a failed configure or close() tore it "
                "down); build a new CRI_network to start another",
                code="no_session",
            )
        return self._client

    # stimulus rows go over the wire as-is: the server canonicalises
    # (sort + dedup) once per row — the documented protocol contract

    def step(self, axon_ids: list[int]) -> list[int]:
        client = self._client_or_raise()
        _check_ids(axon_ids, self._network.n_axons, "axon")
        return client.step(axon_ids)

    def step_many(self, batch: list[list[int]]) -> list[list[int]]:
        # whole-batch range check before any chunk is sent: schedules
        # longer than the server's per-request cap are split by the
        # client, so without this a bad row in a later chunk would
        # execute earlier chunks — diverging from the local backend's
        # atomic validation
        client = self._client_or_raise()
        for row in batch:
            _check_ids(row, self._network.n_axons, "axon")
        return client.step_many(batch)

    def read_membrane(self, ids: list[int]) -> list[int]:
        client = self._client_or_raise()
        _check_ids(ids, self._network.n_neurons, "neuron")
        return client.read_membrane(ids)

    def reset(self) -> None:
        self._client_or_raise().reset()

    def cost(self) -> dict | None:
        return self._client_or_raise().cost()

    def write_synapse(self, pre_is_axon, pre, post, old_weight, new_weight):
        # one protocol round trip: the server upserts the weight into
        # the compiled engine in place (membranes survive), falling back
        # to a journal compaction + rebuild only when the slot layout
        # cannot absorb the edit. A closed session raises like every
        # other op — no silent resurrection.
        client = self._client_or_raise()
        client.write_synapse(int(pre), int(post), int(new_weight),
                             pre_is_axon=bool(pre_is_axon))

    def close(self) -> None:
        if self._client is not None:
            self._client.close()
            self._client = None
        if self._hsn_path is not None:
            try:
                os.unlink(self._hsn_path)
            except OSError:
                pass
            self._hsn_path = None


def make_backend(spec) -> SimBackend:
    """Resolve a ``backend=`` argument: ``"local"``, ``"rust"``, or an
    already-constructed :class:`SimBackend` (passed through)."""
    if isinstance(spec, SimBackend):
        return spec
    if spec == "local":
        return LocalBackend()
    if spec == "rust":
        return RustSessionBackend()
    raise ValueError(
        f"unknown backend {spec!r} (options: 'local', 'rust', or a SimBackend instance)"
    )
