"""Neuron model classes (paper §5.1, Table 1).

Two model classes: LIF (theta, nu, lambda) and ANN/binary (theta, nu).
`nu` is a 6-bit signed noise shift; `stochastic=False` models the
deterministic variants (no noise term at all). `lam` is the 6-bit leak
exponent; lam = 63 approximates an integrate-and-fire neuron.
"""

from __future__ import annotations

from dataclasses import dataclass

FLAG_LIF = 1
FLAG_NOISE = 2

LAM_MAX = 63  # 2^6 - 1
NU_MIN, NU_MAX = -32, 31  # 6-bit signed


@dataclass(frozen=True)
class LIF_neuron:
    """Leaky-integrate-and-fire neuron model: V -= V >> lam each step."""

    theta: int
    nu: int = 0
    lam: int = LAM_MAX
    stochastic: bool = False

    def __post_init__(self):
        if not (NU_MIN <= self.nu <= NU_MAX):
            raise ValueError(f"nu={self.nu} outside 6-bit signed range")
        if not (0 <= self.lam <= LAM_MAX):
            raise ValueError(f"lam={self.lam} outside [0, {LAM_MAX}]")

    @property
    def flags(self) -> int:
        return FLAG_LIF | (FLAG_NOISE if self.stochastic else 0)


@dataclass(frozen=True)
class ANN_neuron:
    """Binary (memoryless) neuron: V is cleared every step after spiking.

    With stochastic=True and nu > -17 it behaves as a Boltzmann-like
    stochastic binary neuron (paper Table 1 note).
    """

    theta: int
    nu: int = 0
    stochastic: bool = False

    def __post_init__(self):
        if not (NU_MIN <= self.nu <= NU_MAX):
            raise ValueError(f"nu={self.nu} outside 6-bit signed range")

    @property
    def lam(self) -> int:  # unused by the update rule; stored as 0
        return 0

    @property
    def flags(self) -> int:
        return FLAG_NOISE if self.stochastic else 0
