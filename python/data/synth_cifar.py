"""Synthetic CIFAR-10: 10 classes of procedurally textured/shaped 32x32
RGB images, bit-sliced into 15 binary channels (5 most-significant bits
per RGB channel) exactly as the paper feeds CIFAR-10 to the spiking CNN
(input shape (15, 32, 32)).
"""

from __future__ import annotations

import numpy as np

N_CLASSES = 10
SIZE = 32
BITS = 5  # bit-slicing depth per colour channel -> 15 binary channels


def _texture(cls: int, rng: np.random.RandomState) -> np.ndarray:
    """32x32x3 float image in [0,1] with class-specific structure."""
    yy, xx = np.mgrid[0:SIZE, 0:SIZE].astype(np.float32) / SIZE
    ph = rng.uniform(0, 2 * np.pi)
    f = rng.uniform(2, 5)
    base = rng.uniform(0.2, 0.8, 3)
    img = np.zeros((SIZE, SIZE, 3), np.float32)
    if cls == 0:  # horizontal stripes
        pat = 0.5 + 0.5 * np.sin(2 * np.pi * f * yy + ph)
    elif cls == 1:  # vertical stripes
        pat = 0.5 + 0.5 * np.sin(2 * np.pi * f * xx + ph)
    elif cls == 2:  # diagonal stripes
        pat = 0.5 + 0.5 * np.sin(2 * np.pi * f * (xx + yy) + ph)
    elif cls == 3:  # rings
        r = np.hypot(xx - rng.uniform(0.3, 0.7), yy - rng.uniform(0.3, 0.7))
        pat = 0.5 + 0.5 * np.sin(2 * np.pi * f * 2 * r + ph)
    elif cls == 4:  # checkerboard
        k = int(rng.randint(3, 6))
        pat = (((xx * k).astype(int) + (yy * k).astype(int)) % 2).astype(np.float32)
    elif cls == 5:  # centered disc
        r = np.hypot(xx - 0.5, yy - 0.5)
        pat = (r < rng.uniform(0.2, 0.35)).astype(np.float32)
    elif cls == 6:  # square
        s = rng.uniform(0.15, 0.3)
        pat = ((np.abs(xx - 0.5) < s) & (np.abs(yy - 0.5) < s)).astype(np.float32)
    elif cls == 7:  # cross
        w = rng.uniform(0.06, 0.12)
        pat = ((np.abs(xx - 0.5) < w) | (np.abs(yy - 0.5) < w)).astype(np.float32)
    elif cls == 8:  # gradient
        a = rng.uniform(0, 2 * np.pi)
        pat = np.clip(np.cos(a) * xx + np.sin(a) * yy, 0, 1)
    else:  # blobs
        pat = np.zeros((SIZE, SIZE), np.float32)
        for _ in range(4):
            cx, cy = rng.uniform(0.1, 0.9, 2)
            r2 = (xx - cx) ** 2 + (yy - cy) ** 2
            pat += np.exp(-r2 / 0.01)
        pat = np.clip(pat, 0, 1)
    hue = rng.permutation(3)
    for c in range(3):
        img[:, :, c] = np.clip(base[c] * 0.4 + pat * (0.6 if hue[c] == 0 else 0.3), 0, 1)
    img += rng.normal(0, 0.04, img.shape)
    return np.clip(img, 0, 1)


def bit_slice(img: np.ndarray) -> np.ndarray:
    """[H,W,3] float -> [15,H,W] binary (5 MSBs per channel)."""
    q = (img * 255).astype(np.uint8)
    planes = []
    for c in range(3):
        for b in range(BITS):
            planes.append((q[:, :, c] >> (7 - b)) & 1)
    return np.stack(planes).astype(np.uint8)


def generate(n: int, seed: int = 0):
    """Return (planes uint8 [n, 15, 32, 32], labels [n])."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, N_CLASSES, n)
    planes = np.stack([bit_slice(_texture(int(c), rng)) for c in labels])
    return planes, labels
