"""Synthetic MNIST: procedural 28x28 binary digit images.

Digits are rendered from polyline stroke skeletons (a hand-designed
vector font), randomly translated, scaled, rotated, thickened and
speckled — enough intra-class variance that the task is learnable but
not trivial. Deterministic given the seed.
"""

from __future__ import annotations

import numpy as np

# Stroke skeletons on a [0,1]^2 canvas: list of polylines per digit.
_STROKES = {
    0: [[(0.5, 0.1), (0.8, 0.3), (0.8, 0.7), (0.5, 0.9), (0.2, 0.7), (0.2, 0.3), (0.5, 0.1)]],
    1: [[(0.35, 0.25), (0.55, 0.1), (0.55, 0.9)], [(0.35, 0.9), (0.75, 0.9)]],
    2: [[(0.2, 0.25), (0.5, 0.1), (0.8, 0.3), (0.2, 0.9), (0.8, 0.9)]],
    3: [[(0.2, 0.15), (0.7, 0.15), (0.45, 0.45), (0.8, 0.7), (0.5, 0.92), (0.2, 0.8)]],
    4: [[(0.65, 0.9), (0.65, 0.1), (0.2, 0.6), (0.85, 0.6)]],
    5: [[(0.8, 0.1), (0.25, 0.1), (0.25, 0.45), (0.65, 0.45), (0.8, 0.7), (0.55, 0.9), (0.2, 0.85)]],
    6: [[(0.7, 0.1), (0.35, 0.4), (0.25, 0.75), (0.5, 0.9), (0.75, 0.7), (0.55, 0.5), (0.3, 0.6)]],
    7: [[(0.2, 0.1), (0.8, 0.1), (0.45, 0.9)], [(0.35, 0.5), (0.7, 0.5)]],
    8: [[(0.5, 0.1), (0.75, 0.28), (0.5, 0.48), (0.25, 0.28), (0.5, 0.1)],
        [(0.5, 0.48), (0.8, 0.7), (0.5, 0.92), (0.2, 0.7), (0.5, 0.48)]],
    9: [[(0.7, 0.4), (0.45, 0.5), (0.3, 0.3), (0.5, 0.1), (0.75, 0.25), (0.7, 0.4), (0.6, 0.9)]],
}


def _render(digit: int, rng: np.random.RandomState, size: int = 28) -> np.ndarray:
    img = np.zeros((size, size), np.float32)
    scale = rng.uniform(0.7, 1.0)
    angle = rng.uniform(-0.25, 0.25)
    dx = rng.uniform(0.05, 0.95 - scale * 0.9)
    dy = rng.uniform(0.05, 0.95 - scale * 0.9)
    ca, sa = np.cos(angle), np.sin(angle)
    thick = rng.uniform(0.8, 1.7)
    for line in _STROKES[digit]:
        pts = np.array(line, np.float32)
        # jitter control points
        pts = pts + rng.normal(0, 0.02, pts.shape).astype(np.float32)
        # rotate around center, scale, translate
        c = pts - 0.5
        pts = np.stack([c[:, 0] * ca - c[:, 1] * sa, c[:, 0] * sa + c[:, 1] * ca], 1) + 0.5
        pts = pts * scale + [dx, dy]
        for (x0, y0), (x1, y1) in zip(pts[:-1], pts[1:]):
            n = max(2, int(np.hypot(x1 - x0, y1 - y0) * size * 2))
            for t in np.linspace(0, 1, n):
                x = (x0 + (x1 - x0) * t) * size
                y = (y0 + (y1 - y0) * t) * size
                yy, xx = np.mgrid[
                    max(0, int(y - 2)) : min(size, int(y + 3)),
                    max(0, int(x - 2)) : min(size, int(x + 3)),
                ]
                d2 = (yy + 0.5 - y) ** 2 + (xx + 0.5 - x) ** 2
                img[yy, xx] = np.maximum(img[yy, xx], (d2 < thick).astype(np.float32))
    # speckle noise
    noise = rng.rand(size, size) < 0.01
    img = np.clip(img + noise, 0, 1)
    drop = rng.rand(size, size) < 0.02
    img = img * (1 - drop)
    return img.astype(np.uint8)


def generate(n: int, seed: int = 0, size: int = 28):
    """Return (images uint8 [n, size, size] binary, labels int64 [n])."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, 10, n)
    images = np.stack([_render(int(d), rng, size) for d in labels])
    return images, labels


if __name__ == "__main__":
    imgs, labels = generate(4, seed=1)
    for img, lab in zip(imgs, labels):
        print(f"--- digit {lab}")
        for row in img:
            print("".join("#" if v else "." for v in row))
