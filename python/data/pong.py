"""Minimal Pong environment + DVS frame conversion (paper §6, Fig 4).

The environment is a 160x210 Atari-like court: the agent's paddle on the
right, a scripted opponent on the left, one ball. Episodes end at 21
points for either side; agent reward = agent points - opponent points
(max +21, the paper's score scale).

DVS conversion (paper's method): compare each frame with the frame four
frames prior; grayscale -> downsample/crop to 84x84 -> ON/OFF change
events with threshold 10 (on 0..255 intensity).

The same environment dynamics are reimplemented in Rust
(`examples/dvs_pong.rs`); the constants here are the spec (keep in sync).
"""

from __future__ import annotations

import numpy as np

W, H = 160, 210
PADDLE_H = 16
PADDLE_W = 4
BALL = 2
AGENT_X = W - 8
OPP_X = 4
ACTIONS = 6  # Atari action set: NOOP FIRE UP DOWN UPFIRE DOWNFIRE
DVS_SIZE = 84
DVS_THRESH = 10
FRAME_LAG = 4


class PongEnv:
    def __init__(self, seed: int = 0):
        self.rng = np.random.RandomState(seed)
        self.reset()

    def reset(self):
        self.agent_y = H // 2
        self.opp_y = H // 2
        self.score = [0, 0]  # [opponent, agent]
        self._serve()
        self.history = [self.render() for _ in range(FRAME_LAG + 1)]
        return self.history[-1]

    def _serve(self):
        self.ball = np.array([W / 2, H / 2], np.float32)
        vx = self.rng.choice([-1.0, 1.0]) * self.rng.uniform(2.0, 3.0)
        vy = self.rng.uniform(-2.0, 2.0)
        self.vel = np.array([vx, vy], np.float32)

    def step(self, action: int):
        """Returns (frame, reward, done)."""
        # agent paddle: UP/UPFIRE = 2,4; DOWN/DOWNFIRE = 3,5
        if action in (2, 4):
            self.agent_y = max(PADDLE_H // 2, self.agent_y - 4)
        elif action in (3, 5):
            self.agent_y = min(H - PADDLE_H // 2, self.agent_y + 4)
        # scripted opponent tracks the ball with limited speed + lag
        target = self.ball[1] + self.rng.normal(0, 4)
        if target > self.opp_y + 2:
            self.opp_y = min(H - PADDLE_H // 2, self.opp_y + 3)
        elif target < self.opp_y - 2:
            self.opp_y = max(PADDLE_H // 2, self.opp_y - 3)

        self.ball += self.vel
        reward = 0.0
        # wall bounce
        if self.ball[1] < BALL or self.ball[1] > H - BALL:
            self.vel[1] = -self.vel[1]
            self.ball[1] = np.clip(self.ball[1], BALL, H - BALL)
        # paddles
        if self.ball[0] >= AGENT_X - PADDLE_W and self.vel[0] > 0:
            if abs(self.ball[1] - self.agent_y) <= PADDLE_H // 2 + BALL:
                self.vel[0] = -abs(self.vel[0]) * 1.05
                self.vel[1] += (self.ball[1] - self.agent_y) * 0.15
                self.ball[0] = AGENT_X - PADDLE_W
            elif self.ball[0] > W:
                self.score[0] += 1
                reward = -1.0
                self._serve()
        if self.ball[0] <= OPP_X + PADDLE_W and self.vel[0] < 0:
            if abs(self.ball[1] - self.opp_y) <= PADDLE_H // 2 + BALL:
                self.vel[0] = abs(self.vel[0]) * 1.05
                self.vel[1] += (self.ball[1] - self.opp_y) * 0.15
                self.ball[0] = OPP_X + PADDLE_W
            elif self.ball[0] < 0:
                self.score[1] += 1
                reward = 1.0
                self._serve()
        self.vel[0] = np.clip(self.vel[0], -6, 6)
        self.vel[1] = np.clip(self.vel[1], -5, 5)

        frame = self.render()
        self.history.append(frame)
        if len(self.history) > FRAME_LAG + 1:
            self.history.pop(0)
        done = max(self.score) >= 21
        return frame, reward, done

    def render(self) -> np.ndarray:
        """Grayscale frame [H, W] uint8."""
        f = np.zeros((H, W), np.uint8)
        ay = int(self.agent_y)
        oy = int(self.opp_y)
        f[max(0, ay - PADDLE_H // 2) : ay + PADDLE_H // 2, AGENT_X : AGENT_X + PADDLE_W] = 200
        f[max(0, oy - PADDLE_H // 2) : oy + PADDLE_H // 2, OPP_X : OPP_X + PADDLE_W] = 200
        bx, by = int(self.ball[0]), int(self.ball[1])
        f[max(0, by - BALL) : by + BALL, max(0, bx - BALL) : bx + BALL] = 255
        return f

    def dvs_obs(self) -> np.ndarray:
        """[2, 84, 84] binary ON/OFF events vs the frame 4 steps back."""
        cur = self.history[-1]
        old = self.history[0]
        return dvs_frame(cur, old)

    def expert_action(self) -> int:
        """Scripted expert: track the ball (used for behaviour cloning)."""
        if self.ball[1] > self.agent_y + 3:
            return 3
        if self.ball[1] < self.agent_y - 3:
            return 2
        return 0


def dvs_frame(cur: np.ndarray, old: np.ndarray) -> np.ndarray:
    """Downsample 160x210 -> 84x84 (crop top/bottom margin, 2x2 mean),
    then ON/OFF threshold on the intensity change."""
    # crop to 168 rows centered, downsample by 2 -> 84x80, pad to 84
    c0 = (H - 168) // 2
    cur_c = cur[c0 : c0 + 168, :].astype(np.int16)
    old_c = old[c0 : c0 + 168, :].astype(np.int16)

    def ds(f):
        return f.reshape(84, 2, 80, 2).mean(axis=(1, 3))

    d = ds(cur_c) - ds(old_c)
    on = np.zeros((84, 84), np.uint8)
    off = np.zeros((84, 84), np.uint8)
    on[:, 2:82] = d > DVS_THRESH
    off[:, 2:82] = d < -DVS_THRESH
    return np.stack([on, off])


def collect_bc_dataset(n_frames: int, seed: int = 0):
    """Behaviour-cloning dataset: (obs [n,2,84,84] uint8, actions [n])."""
    env = PongEnv(seed)
    obs, acts = [], []
    while len(obs) < n_frames:
        a = env.expert_action()
        _, _, done = env.step(a)
        obs.append(env.dvs_obs())
        acts.append(a)
        if done:
            env.reset()
    return np.stack(obs), np.array(acts, np.int64)


if __name__ == "__main__":
    import sys

    if "--demo" in sys.argv:
        env = PongEnv(1)
        for _ in range(30):
            env.step(env.expert_action())
        o = env.dvs_obs()
        print(f"ON events: {o[0].sum()}, OFF events: {o[1].sum()}")
        for y in range(0, 84, 2):
            print("".join(
                "+" if o[0, y, x] else ("-" if o[1, y, x] else ".") for x in range(84)
            ))
