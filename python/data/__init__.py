"""Synthetic dataset generators.

The build environment is offline (no MNIST/DVS-Gesture/CIFAR-10
downloads), so each benchmark dataset is replaced by a deterministic
procedural generator with the same shapes, channel conventions and task
structure (DESIGN.md "Substitutions"). Table 2's headline results —
software<->hardware accuracy parity and energy/latency scaling — are
dataset-agnostic; absolute accuracies reported in EXPERIMENTS.md are for
these synthetic sets.
"""
