"""AOT-lower the L2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text (NOT `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the `xla` crate
links) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/gen_hlo.py.

Also emits golden test vectors (artifacts/golden/*.json) that the Rust
test suite checks bit-exactly against its own engines, closing the
python<->rust loop without python on the request path.

Usage: python -m compile.aot [--out-dir ../artifacts]
`make artifacts` calls this once; it is a no-op if inputs are unchanged
(handled by make's dependency tracking).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

# Shape variants compiled ahead of time. The Rust runtime picks the
# smallest variant that fits the partitioned core (see
# rust/src/runtime/registry.rs). Capacities are powers of two; the
# hardware core capacity ceiling is 4M neurons/FPGA over 32 cores
# = 128K neurons/core.
NEURON_UPDATE_SIZES = [1024, 4096, 16384, 65536, 131072]
SYNAPSE_ACCUM_SIZES = [(1024, 4096), (4096, 16384), (16384, 16384),
                       (16384, 65536), (65536, 65536), (131072, 65536)]
DENSE_STEP_SIZES = [(256, 256), (1024, 1024), (2048, 2048)]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> list[str]:
    written = []

    def emit(name, fn, spec):
        lowered = jax.jit(fn).lower(*spec)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        written.append(path)
        print(f"  {name}: {len(text)} chars")

    for n in NEURON_UPDATE_SIZES:
        emit(f"neuron_update_n{n}", model.neuron_update_fn, model.neuron_update_spec(n))
    for n, e in SYNAPSE_ACCUM_SIZES:
        emit(f"synapse_accum_n{n}_e{e}", model.synapse_accum_fn,
             model.synapse_accum_spec(n, e))
    for n, a in DENSE_STEP_SIZES:
        emit(f"dense_step_n{n}_a{a}", model.dense_step_fn, model.dense_step_spec(n, a))
    return written


def golden_vectors(out_dir: str) -> None:
    """Deterministic cross-language test vectors, checked by Rust tests."""
    gdir = os.path.join(out_dir, "golden")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.RandomState(0xC0FFEE % (2**31))

    # --- prng golden: mix_seed + noise17 over a few (seed, idx) pairs
    seeds = [1, 0xDEADBEEF, 0x12345678, 2**32 - 1]
    prng = {"mix_seed": [], "noise17": []}
    for s in seeds:
        for step in [0, 1, 7, 1000]:
            ms = int(ref.mix_seed(s, step))
            prng["mix_seed"].append([s, step, ms])
        for idx in [0, 1, 255, 131071]:
            prng["noise17"].append([s, idx, int(ref.noise17(jnp.uint32(s), idx))])
    with open(os.path.join(gdir, "prng.json"), "w") as f:
        json.dump(prng, f)

    # --- neuron_update golden: randomized params, N=1024
    n = 1024
    v = rng.randint(-(2**20), 2**20, n).astype(np.int32)
    theta = rng.randint(0, 2**16, n).astype(np.int32)
    nu = rng.randint(-32, 32, n).astype(np.int32)
    lam = rng.randint(0, 64, n).astype(np.int32)
    flags = rng.randint(0, 4, n).astype(np.int32)
    step_seed = int(ref.mix_seed(42, 3))
    v2, s = ref.neuron_update_ref(v, theta, nu, lam, flags, jnp.uint32(step_seed))
    golden = {
        "n": n,
        "step_seed": step_seed,
        "v": v.tolist(), "theta": theta.tolist(), "nu": nu.tolist(),
        "lam": lam.tolist(), "flags": flags.tolist(),
        "v_out": np.asarray(v2).tolist(), "spikes": np.asarray(s).tolist(),
    }
    with open(os.path.join(gdir, "neuron_update.json"), "w") as f:
        json.dump(golden, f)

    # --- synapse_accum golden with padding drops
    e = 4096
    targets = rng.randint(0, n + 1, e).astype(np.int32)  # n == dropped pad
    weights = rng.randint(-(2**15), 2**15, e).astype(np.int32)
    v3 = np.asarray(ref.synapse_accum_ref(v, targets, weights))
    with open(os.path.join(gdir, "synapse_accum.json"), "w") as f:
        json.dump({"n": n, "e": e, "v": v.tolist(), "targets": targets.tolist(),
                   "weights": weights.tolist(), "v_out": v3.tolist()}, f)

    # --- multi-step dense network golden (drives the three-way parity test)
    nn, na, steps = 64, 16, 12
    w_neuron = (rng.randint(-40, 40, (nn, nn)) * (rng.rand(nn, nn) < 0.2)).astype(np.int32)
    w_axon = (rng.randint(-40, 40, (na, nn)) * (rng.rand(na, nn) < 0.5)).astype(np.int32)
    theta = rng.randint(10, 120, nn).astype(np.int32)
    nu = rng.randint(-8, 4, nn).astype(np.int32)
    lam = rng.randint(1, 64, nn).astype(np.int32)
    flags = rng.randint(0, 4, nn).astype(np.int32)
    v = np.zeros(nn, np.int32)
    axon_seq = (rng.rand(steps, na) < 0.3).astype(np.int32)
    base_seed = 777
    spikes_hist, v_hist = [], []
    for t in range(steps):
        ss = ref.mix_seed(base_seed, t)
        v, s = ref.dense_step_ref(v, theta, nu, lam, flags, ss,
                                  w_neuron, w_axon, axon_seq[t])
        v = np.asarray(v)
        spikes_hist.append(np.asarray(s).tolist())
        v_hist.append(v.tolist())
    with open(os.path.join(gdir, "dense_net.json"), "w") as f:
        json.dump({"n": nn, "a": na, "steps": steps, "base_seed": base_seed,
                   "w_neuron": w_neuron.tolist(), "w_axon": w_axon.tolist(),
                   "theta": theta.tolist(), "nu": nu.tolist(), "lam": lam.tolist(),
                   "flags": flags.tolist(), "axon_seq": axon_seq.tolist(),
                   "spikes": spikes_hist, "v": v_hist}, f)
    print(f"  golden vectors -> {gdir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__),
                                                      "..", "..", "artifacts"))
    ap.add_argument("--skip-large", action="store_true",
                    help="skip the >=64K variants (CI fast path)")
    args = ap.parse_args()
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)
    if args.skip_large:
        global NEURON_UPDATE_SIZES, SYNAPSE_ACCUM_SIZES
        NEURON_UPDATE_SIZES = [s for s in NEURON_UPDATE_SIZES if s <= 16384]
        SYNAPSE_ACCUM_SIZES = [(n, e) for n, e in SYNAPSE_ACCUM_SIZES if n <= 16384]
    print(f"lowering artifacts -> {out}")
    lower_all(out)
    golden_vectors(out)
    # stamp for make freshness
    with open(os.path.join(out, ".stamp"), "w") as f:
        f.write("ok\n")


if __name__ == "__main__":
    main()
