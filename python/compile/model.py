"""L2: the HiAER-Spike per-timestep compute graphs, built on the L1 kernel.

Three executables (each AOT-lowered to HLO text by aot.py and executed
from the Rust runtime):

* neuron_update(N)     — phases 1-3 (noise / spike+reset / leak) via the
                         Pallas kernel; returns (V', spikes).
* synapse_accum(N, E)  — phase 4: scatter-add E gathered (target, weight)
                         synaptic events into V. Padded events carry
                         target == N and are dropped. This is the compute
                         half of the HBM two-phase routing: L3 Rust walks
                         the HBM adjacency table (counting accesses) and
                         hands the gathered events here.
* dense_step(N, A)     — the full Fig-8 software-simulator step with dense
                         weight matrices (used for the CPU software
                         baseline the paper compares throughput against).

All graphs are int32-pure and bit-exact with kernels.ref.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import neuron_update as pallas_neuron_update
from .kernels import ref


def neuron_update_fn(v, theta, nu, lam, flags, step_seed):
    """(V, params, seed) -> (V', spikes) — Pallas-kerneled phases 1-3."""
    seed = jnp.asarray(step_seed, jnp.uint32).reshape(())
    v2, s = pallas_neuron_update(v, theta, nu, lam, flags, seed)
    return v2, s


def synapse_accum_fn(v, targets, weights):
    """(V, events) -> V'. targets/weights are int32[E]; target==N drops."""
    return ref.synapse_accum_ref(v, targets, weights)


def dense_step_fn(v, theta, nu, lam, flags, step_seed, w_neuron, w_axon, axon_in):
    """Full dense timestep (Fig 8), Pallas kernel for phases 1-3."""
    v2, s = neuron_update_fn(v, theta, nu, lam, flags, step_seed)
    contrib = s @ w_neuron + axon_in @ w_axon
    return v2 + contrib, s


def neuron_update_spec(n: int):
    """Example-args spec for lowering neuron_update at capacity n."""
    i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    u32 = jax.ShapeDtypeStruct((), jnp.uint32)
    return (i32(n), i32(n), i32(n), i32(n), i32(n), u32)


def synapse_accum_spec(n: int, e: int):
    i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    return (i32(n), i32(e), i32(e))


def dense_step_spec(n: int, a: int):
    i32 = lambda *shape: jax.ShapeDtypeStruct(shape, jnp.int32)  # noqa: E731
    u32 = jax.ShapeDtypeStruct((), jnp.uint32)
    return (i32(n), i32(n), i32(n), i32(n), i32(n), u32, i32(n, n), i32(a, n), i32(a))
