"""L1 Pallas kernel: the HiAER-Spike membrane-update hot spot.

The FPGA updates neurons sequentially in 16-wide parallel port groups fed
from URAM membrane registers. On TPU the analogous schedule is: tile the
neuron state into VMEM-resident blocks and run phases 1-3 (noise, spike +
reset, leak) elementwise per block on the VPU — there is no matmul here,
so the MXU is idle by design; the kernel is memory-streaming and its
roofline is HBM->VMEM bandwidth. BlockSpec expresses the HBM<->VMEM
schedule that the FPGA expresses with its URAM banking.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO so the Rust runtime
can run the artifact. Real-TPU perf is estimated in DESIGN.md from the
VMEM footprint (BLOCK * 5 int32 arrays = 5 KiB/block at BLOCK=256).

Bit-exact contract: must match kernels.ref.neuron_update_ref for all
inputs. Verified by python/tests/test_kernel.py (hypothesis sweeps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import FLAG_LIF, FLAG_NOISE

# numpy scalar (not jnp array): jnp constants would be captured consts,
# which pallas_call rejects.
_PHI32 = np.uint32(0x9E3779B9)

# Default VMEM tile: 256 neurons x 5 int32 in-arrays + 2 out-arrays
# = 7 KiB per grid step. Chosen by the block-size sweep in
# python/tests/test_kernel.py::test_block_size_equivalence; any multiple
# of 128 lanes is valid.
DEFAULT_BLOCK = 256


def _noise17_block(step_seed, base, n):
    """noise17 for indices [base, base+n) as uint32 vector ops.

    Identical arithmetic to ref.noise17 (double-round xorshift32 hash of
    step_seed ^ idx*phi32) so the artifact and all Rust engines agree.
    """
    idx = base + jax.lax.broadcasted_iota(jnp.uint32, (n,), 0)
    x = step_seed ^ (idx * _PHI32)
    for _ in range(2):
        x = x ^ (x << np.uint32(13))
        x = x ^ (x >> np.uint32(17))
        x = x ^ (x << np.uint32(5))
    lo = (x & np.uint32(0x1FFFF)).astype(jnp.int32)
    return (lo - np.int32(1 << 16)) | np.int32(1)


def _neuron_update_kernel(
    seed_ref, v_ref, theta_ref, nu_ref, lam_ref, flags_ref, v_out_ref, s_out_ref, *, block
):
    """One VMEM block of phases 1-3. seed_ref is a (1,) scalar block."""
    pid = pl.program_id(0)
    base = pid.astype(jnp.uint32) * jnp.uint32(block)

    v = v_ref[...]
    theta = theta_ref[...]
    nu = nu_ref[...]
    lam = lam_ref[...]
    flags = flags_ref[...]
    step_seed = seed_ref[0].astype(jnp.uint32)

    # 1. noise (stochastic neurons only)
    xi = _noise17_block(step_seed, base, block)
    left = jnp.clip(nu, 0, 31)
    right = jnp.clip(-nu, 0, 31)
    xi = jnp.where(nu >= 0, xi << left, xi >> right).astype(jnp.int32)
    v = jnp.where((flags & FLAG_NOISE) != 0, v + xi, v)

    # 2. spike threshold (strict >) + hard reset to 0
    spikes = (v > theta).astype(jnp.int32)
    v = jnp.where(spikes != 0, jnp.int32(0), v)

    # 3. leak: LIF v -= v >> lam; ANN v = 0
    lam_c = jnp.clip(lam, 0, 31)
    v = jnp.where((flags & FLAG_LIF) != 0, v - (v >> lam_c), jnp.int32(0))

    v_out_ref[...] = v
    s_out_ref[...] = spikes


def neuron_update(v, theta, nu, lam, flags, step_seed, *, block: int = DEFAULT_BLOCK):
    """Pallas-tiled neuron update. N must be a multiple of `block`
    (the AOT path always pads cores to a power-of-two capacity).

    Returns (v_next int32[N], spikes int32[N]).
    """
    n = v.shape[0]
    if n % block != 0:
        raise ValueError(f"N={n} must be a multiple of block={block}")
    grid = (n // block,)
    bspec = pl.BlockSpec((block,), lambda i: (i,))
    seed_spec = pl.BlockSpec((1,), lambda i: (0,))
    seed_arr = jnp.asarray(step_seed, jnp.uint32).reshape((1,))
    kernel = functools.partial(_neuron_update_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[seed_spec, bspec, bspec, bspec, bspec, bspec],
        out_specs=[bspec, bspec],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=True,
    )(seed_arr, v, theta, nu, lam, flags)
