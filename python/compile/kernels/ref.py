"""Pure-jnp oracle for the HiAER-Spike neuron-update semantics (Table 1 / Fig 8).

This module is the single source of truth for the bit-level neuron dynamics.
The Pallas kernel (neuron_update.py), the Rust dense engine
(rust/src/engine/dense.rs), and the Rust event-driven HBM engine
(rust/src/engine/core.rs) must all agree bit-exactly with these functions.

Per-timestep order of operations (exactly the hardware / Fig-8 simulator):

  1. noise:    V += xi            (only if the neuron's model is stochastic)
               xi = (U17 | 1) << nu   (nu >= 0)   or   >> -nu   (nu < 0)
               U17 ~ 17-bit uniform in [-2^16, 2^16), LSB forced to 1
  2. spike:    S = (V > theta)  (strict >);  V[S] = 0
  3. membrane: LIF:  V = V - (V >> lam)      (arithmetic shift = floor div)
               ANN:  V = 0
  4. integrate:V += sum_j w_ij * S_j  + axon inputs   (same step's spikes)

All state is int32; weights are int16 widened to int32. lam is clamped to
[0, 31]: for int32 V, V >> 31 equals floor(V / 2^63) for every
representable V (0 for V >= 0, -1 for V < 0), so the hardware's 6-bit
lam in [32, 63] is exactly represented by a 31 shift.

Noise PRNG: a counter-based double-round xorshift32 hash of
(step_seed, neuron_index) — deterministic, stateless, and cheap enough to
implement identically in jnp, Pallas, and Rust (rust/src/util/prng.rs).
"""

from __future__ import annotations

import jax.numpy as jnp

# Neuron flag bits (mirrored in rust/src/snn/neuron.rs).
FLAG_LIF = 1  # bit0: 1 = LIF membrane update, 0 = ANN (memoryless binary)
FLAG_NOISE = 2  # bit1: 1 = stochastic (apply the noise update)

GOLDEN_RATIO32 = jnp.uint32(0x9E3779B9)


def mix_seed(base_seed, step):
    """Per-step seed: one xorshift round over base ^ (step * phi32).

    Must match rust/src/util/prng.rs::mix_seed bit-for-bit.
    """
    base_seed = jnp.uint32(base_seed)
    step = jnp.uint32(step)
    x = base_seed ^ (step * GOLDEN_RATIO32)
    x = x ^ (x << jnp.uint32(13))
    x = x ^ (x >> jnp.uint32(17))
    x = x ^ (x << jnp.uint32(5))
    # avoid the all-zero fixed point of xorshift
    return x | jnp.uint32(1)


def noise17(step_seed, idx):
    """17-bit odd uniform noise per neuron index (int32 result).

    Counter-based: hash(step_seed, idx) -> low 17 bits -> [-2^16, 2^16) -> |1.
    Matches rust/src/util/prng.rs::noise17.
    """
    x = jnp.uint32(step_seed) ^ (jnp.asarray(idx, jnp.uint32) * GOLDEN_RATIO32)
    for _ in range(2):
        x = x ^ (x << jnp.uint32(13))
        x = x ^ (x >> jnp.uint32(17))
        x = x ^ (x << jnp.uint32(5))
    lo = (x & jnp.uint32(0x1FFFF)).astype(jnp.int32)  # [0, 2^17)
    v = lo - jnp.int32(1 << 16)  # [-2^16, 2^16)
    return v | jnp.int32(1)  # odd, balanced around 0


def shift_noise(xi, nu):
    """Apply the nu scaling shift: left shift for nu>0, arithmetic right
    shift for nu<0. Shift amounts clamp to [0, 31] (int32 registers)."""
    nu = jnp.asarray(nu, jnp.int32)
    left = jnp.clip(nu, 0, 31)
    right = jnp.clip(-nu, 0, 31)
    shifted = jnp.where(nu >= 0, xi << left, xi >> right)
    return shifted.astype(jnp.int32)


def neuron_update_ref(v, theta, nu, lam, flags, step_seed):
    """Phases 1-3 of the timestep: noise, spike/reset, leak.

    Args:
      v:     int32[N] membrane potentials
      theta: int32[N] spike thresholds
      nu:    int32[N] noise shift exponents (6-bit signed semantics)
      lam:   int32[N] leak exponents (clamped to 31)
      flags: int32[N] bitfield (FLAG_LIF | FLAG_NOISE)
      step_seed: uint32 scalar (mix_seed(base, step))

    Returns: (v_next int32[N], spikes int32[N] in {0,1})
    """
    v = jnp.asarray(v, jnp.int32)
    n = v.shape[0]
    idx = jnp.arange(n, dtype=jnp.uint32)

    # 1. noise
    xi = shift_noise(noise17(step_seed, idx), nu)
    noisy = (jnp.asarray(flags, jnp.int32) & FLAG_NOISE) != 0
    v = jnp.where(noisy, v + xi, v)

    # 2. spike + reset (strict >)
    spikes = (v > jnp.asarray(theta, jnp.int32)).astype(jnp.int32)
    v = jnp.where(spikes != 0, jnp.int32(0), v)

    # 3. leak (LIF) or clear (ANN)
    lam_c = jnp.clip(jnp.asarray(lam, jnp.int32), 0, 31)
    is_lif = (jnp.asarray(flags, jnp.int32) & FLAG_LIF) != 0
    v = jnp.where(is_lif, v - (v >> lam_c), jnp.int32(0))

    return v, spikes


def synapse_accum_ref(v, targets, weights):
    """Phase 4: scatter-add gathered synaptic events into V.

    Padding convention: target == N (out of range) entries are dropped.
    """
    v = jnp.asarray(v, jnp.int32)
    return v.at[jnp.asarray(targets, jnp.int32)].add(
        jnp.asarray(weights, jnp.int32), mode="drop"
    )


def dense_step_ref(v, theta, nu, lam, flags, step_seed, w_neuron, w_axon, axon_in):
    """One full timestep with dense weight matrices — the Fig-8 software
    simulator. w_neuron[i, j] = weight of synapse i -> j (pre-major),
    w_axon[a, j] likewise for axons. axon_in is the 0/1 axon firing vector.

    Returns (v_next, spikes).
    """
    v, spikes = neuron_update_ref(v, theta, nu, lam, flags, step_seed)
    contrib = spikes @ jnp.asarray(w_neuron, jnp.int32)
    contrib = contrib + jnp.asarray(axon_in, jnp.int32) @ jnp.asarray(w_axon, jnp.int32)
    return v + contrib, spikes
