"""HiAER-Spike L1 kernels: the Pallas membrane-update kernel and its
pure-jnp oracle."""

from . import ref  # noqa: F401
from .neuron_update import neuron_update  # noqa: F401
