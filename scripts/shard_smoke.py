#!/usr/bin/env python3
"""End-to-end smoke for `Backend::Sharded` on the release binary.

Drives `hiaer-spike serve-session --cores 2` the way an operator would —
real shard-worker subprocesses, not reachable through `cargo test`:

1. start a `serve-session` child on stdio with a 2-core topology;
2. `configure` with `"shards": 2` (the session-protocol field added in
   PR 8) and run a few healthy steps;
3. find the two `shard-worker` grandchildren via /proc and SIGKILL one;
4. require the next step to answer a typed `"code": "engine"` error
   naming the dead shard — never a hang;
5. `shutdown`, then require every worker pid to vanish from /proc
   (dead *and* reaped: zombies keep their /proc entry).

Stdlib only; a watchdog plus per-read timeouts bound every phase so a
wedged parent or worker fails the run instead of hanging CI. Exit
code 0 = pass.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import threading
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def find_binary(explicit: str | None) -> str:
    if explicit:
        return explicit
    env = os.environ.get("HS_BIN")
    if env:
        return env
    for rel in ("rust/target/release/hiaer-spike", "target/release/hiaer-spike",
                "rust/target/debug/hiaer-spike", "target/debug/hiaer-spike"):
        cand = os.path.join(REPO, rel)
        if os.path.isfile(cand) and os.access(cand, os.X_OK):
            return cand
    sys.exit("shard_smoke: no hiaer-spike binary (build with `cargo build "
             "--release`, or pass --binary / set $HS_BIN)")


class Session:
    """One serve-session child; each recv is deadline-bounded."""

    def __init__(self, argv: list[str], timeout: float):
        self.timeout = timeout
        self.proc = subprocess.Popen(
            argv, stdin=subprocess.PIPE, stdout=subprocess.PIPE,
            stderr=subprocess.PIPE, text=True)

    def send(self, req: dict) -> None:
        assert self.proc.stdin is not None
        self.proc.stdin.write(json.dumps(req, separators=(",", ":")) + "\n")
        self.proc.stdin.flush()

    def recv(self) -> dict:
        # readline on a thread so a wedged child trips the deadline
        # instead of blocking the smoke forever
        box: list[str] = []
        t = threading.Thread(target=lambda: box.append(self.proc.stdout.readline()))
        t.daemon = True
        t.start()
        t.join(timeout=self.timeout)
        assert not t.is_alive(), f"no response within {self.timeout}s (parent wedged)"
        assert box and box[0], "serve-session closed stdout unexpectedly"
        return json.loads(box[0])

    def request(self, req: dict) -> dict:
        self.send(req)
        resp = self.recv()
        assert resp.get("ok"), f"{req.get('op')} failed: {resp}"
        return resp


def shard_worker_pids(parent_pid: int) -> list[int]:
    """Direct children of `parent_pid` whose cmdline says shard-worker."""
    pids = []
    for entry in os.listdir("/proc"):
        if not entry.isdigit():
            continue
        try:
            with open(f"/proc/{entry}/stat") as f:
                # field 4 (after the parenthesised comm) is ppid
                ppid = int(f.read().rsplit(")", 1)[1].split()[1])
            with open(f"/proc/{entry}/cmdline", "rb") as f:
                cmdline = f.read()
        except (OSError, ValueError, IndexError):
            continue  # raced a process exit
        if ppid == parent_pid and b"shard-worker" in cmdline:
            pids.append(int(entry))
    return sorted(pids)


def wait_until(deadline_s: float, cond) -> bool:
    t0 = time.monotonic()
    while time.monotonic() - t0 < deadline_s:
        if cond():
            return True
        time.sleep(0.05)
    return cond()


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--binary", help="hiaer-spike binary (default: discover)")
    ap.add_argument("--net", default=os.path.join(REPO, "testdata", "fig6_golden.hsn"))
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="hard wall-clock bound for the whole smoke (s)")
    args = ap.parse_args()
    binary = find_binary(args.binary)
    assert os.path.isfile(args.net), f"missing net fixture: {args.net}"

    # --shard-timeout-ms keeps the post-kill step bounded well inside
    # the watchdog even if the kill lands mid-frame
    s = Session([binary, "serve-session", "--cores", "2",
                 "--shard-timeout-ms", "10000"],
                timeout=max(10.0, args.timeout / 4))
    watchdog = threading.Timer(args.timeout, s.proc.kill)
    watchdog.daemon = True
    watchdog.start()
    try:
        hello = s.recv()
        assert hello.get("op") == "hello" and hello.get("ok"), f"bad greeting: {hello}"

        s.request({"op": "configure", "net": args.net, "seed": 7, "shards": 2})
        for _ in range(3):
            s.request({"op": "step", "axons": [0, 1]})
        workers = shard_worker_pids(s.proc.pid)
        assert len(workers) == 2, f"want 2 shard workers under {s.proc.pid}, found {workers}"
        print(f"shard_smoke: configured shards=2, workers up: {workers}")

        os.kill(workers[1], signal.SIGKILL)
        # the kill races in-flight pipes: poll until the typed error lands
        deadline = time.monotonic() + args.timeout / 2
        while True:
            s.send({"op": "step", "axons": [0]})
            resp = s.recv()
            if not resp.get("ok"):
                break
            assert time.monotonic() < deadline, "killed worker never surfaced an error"
            time.sleep(0.05)
        assert resp.get("code") == "engine", f"want code=engine, got: {resp}"
        assert "shard" in json.dumps(resp), f"error should name the shard: {resp}"
        print(f"shard_smoke: killed worker -> typed engine error: "
              f"{resp.get('error', resp)}")

        s.request({"op": "shutdown"})
        s.proc.stdin.close()
        out, err = s.proc.communicate(timeout=args.timeout / 4)
        assert s.proc.returncode == 0, (
            f"serve-session exited {s.proc.returncode}\nstdout: {out}\nstderr: {err}")
        assert wait_until(10.0, lambda: all(not os.path.exists(f"/proc/{p}")
                                           for p in workers)), \
            f"worker pids {workers} still present after shutdown (zombie/orphan)"
        print("shard_smoke: shutdown -> all workers reaped, exit 0. PASS")
        return 0
    except AssertionError as e:
        print(f"shard_smoke: FAIL: {e}", file=sys.stderr)
        return 1
    finally:
        watchdog.cancel()
        if s.proc.poll() is None:
            s.proc.kill()
            s.proc.wait()


if __name__ == "__main__":
    sys.exit(main())
