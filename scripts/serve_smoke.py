#!/usr/bin/env python3
"""End-to-end smoke for the shared serving tier (`hiaer-spike serve`).

Drives the *release binary* the way an operator would — not reachable
through `cargo test`:

1. start `hiaer-spike serve --listen 127.0.0.1:0` (ephemeral port) with
   tight limits and parse the announced address from stdout;
2. run 4 concurrent TCP clients (configure + step_many) — one of them
   disconnects mid-batch without reading its response;
3. check the server still answers `health` (not draining, 0 queue);
4. send SIGTERM and require a clean drain: exit code 0 and the
   "drained" line on stdout.

Stdlib only; every phase is timeout-bounded so a wedged server fails
the run instead of hanging CI. Exit code 0 = pass.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def find_binary(explicit: str | None) -> str:
    if explicit:
        return explicit
    env = os.environ.get("HS_BIN")
    if env:
        return env
    for rel in ("rust/target/release/hiaer-spike", "target/release/hiaer-spike",
                "rust/target/debug/hiaer-spike", "target/debug/hiaer-spike"):
        cand = os.path.join(REPO, rel)
        if os.path.isfile(cand) and os.access(cand, os.X_OK):
            return cand
    sys.exit("serve_smoke: no hiaer-spike binary (build with `cargo build "
             "--release`, or pass --binary / set $HS_BIN)")


class Client:
    """Minimal line-protocol client over one TCP connection."""

    def __init__(self, addr: tuple[str, int], timeout: float):
        self.sock = socket.create_connection(addr, timeout=timeout)
        self.sock.settimeout(timeout)
        self.rfile = self.sock.makefile("r", encoding="utf-8", newline="\n")
        self.wfile = self.sock.makefile("w", encoding="utf-8", newline="\n")
        hello = self.recv()
        assert hello.get("op") == "hello" and hello.get("ok"), f"bad greeting: {hello}"

    def send(self, req: dict) -> None:
        self.wfile.write(json.dumps(req, separators=(",", ":")) + "\n")
        self.wfile.flush()

    def recv(self) -> dict:
        line = self.rfile.readline()
        assert line, "server closed the connection unexpectedly"
        return json.loads(line)

    def request(self, req: dict) -> dict:
        self.send(req)
        resp = self.recv()
        assert resp.get("ok"), f"{req.get('op')} failed: {resp}"
        return resp

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def client_task(i: int, addr: tuple[str, int], net: str, timeout: float,
                errors: list[str]) -> None:
    try:
        c = Client(addr, timeout)
        c.request({"op": "configure", "net": net, "seed": 7})
        if i == 0:
            # the rude client: fire a long batch and vanish mid-flight
            c.send({"op": "step_many", "batch": [[0, 1] if s % 3 == 0 else []
                                                 for s in range(200)]})
            c.close()
            return
        resp = c.request({"op": "step_many",
                          "batch": [[0, 1] if s % 2 == 0 else [] for s in range(50)]})
        assert len(resp["spikes"]) == 50, f"client {i}: want 50 rows, got {len(resp['spikes'])}"
        c.request({"op": "shutdown"})
        c.close()
    except Exception as e:  # noqa: BLE001 — collected and failed centrally
        errors.append(f"client {i}: {type(e).__name__}: {e}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--binary", help="hiaer-spike binary (default: discover)")
    ap.add_argument("--net", default=os.path.join(REPO, "testdata", "fig6_golden.hsn"))
    ap.add_argument("--timeout", type=float, default=60.0,
                    help="hard wall-clock bound for the whole smoke (s)")
    args = ap.parse_args()
    binary = find_binary(args.binary)
    assert os.path.isfile(args.net), f"missing net fixture: {args.net}"

    proc = subprocess.Popen(
        [binary, "serve", "--listen", "127.0.0.1:0",
         "--max-sessions", "8", "--concurrency", "2",
         "--request-timeout-ms", "10000", "--drain-grace-ms", "10000"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
    )
    # hard bound: a wedged server gets killed and the smoke fails
    watchdog = threading.Timer(args.timeout, proc.kill)
    watchdog.daemon = True
    watchdog.start()
    try:
        line = proc.stdout.readline()
        assert line.startswith("listening on "), f"unexpected first line: {line!r}"
        host, _, port = line.strip().rpartition(" ")[2].rpartition(":")
        addr = (host, int(port))
        print(f"serve_smoke: server up at {addr[0]}:{addr[1]}")

        per_client_timeout = max(5.0, args.timeout / 4)
        errors: list[str] = []
        threads = [threading.Thread(target=client_task,
                                    args=(i, addr, args.net, per_client_timeout, errors))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=per_client_timeout)
            assert not t.is_alive(), "client thread wedged"
        assert not errors, "client failures:\n  " + "\n  ".join(errors)
        print("serve_smoke: 4 concurrent clients done (1 disconnected mid-batch)")

        # the rude disconnect must not have hurt the server
        c = Client(addr, per_client_timeout)
        health = c.request({"op": "health"})
        assert health.get("draining") is False, f"server draining early: {health}"
        metrics = c.request({"op": "metrics"})
        assert metrics.get("disconnects", 0) >= 1, f"mid-batch disconnect not seen: {metrics}"
        assert metrics.get("steps_total", 0) >= 150, f"too few steps executed: {metrics}"
        c.request({"op": "shutdown"})
        c.close()
        print(f"serve_smoke: healthy after the fault "
              f"(steps_total={metrics.get('steps_total')}, "
              f"disconnects={metrics.get('disconnects')})")

        proc.send_signal(signal.SIGTERM)
        out, err = proc.communicate(timeout=args.timeout)
        assert proc.returncode == 0, (
            f"server exited {proc.returncode} on SIGTERM\nstdout: {out}\nstderr: {err}")
        assert "drained" in out, f"no drain confirmation on stdout: {out!r}"
        print("serve_smoke: SIGTERM -> clean drain, exit 0. PASS")
        return 0
    except AssertionError as e:
        print(f"serve_smoke: FAIL: {e}", file=sys.stderr)
        return 1
    finally:
        watchdog.cancel()
        if proc.poll() is None:
            proc.kill()
            proc.wait()


if __name__ == "__main__":
    sys.exit(main())
