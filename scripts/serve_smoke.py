#!/usr/bin/env python3
"""End-to-end smoke for the shared serving tier (`hiaer-spike serve`).

Drives the *release binary* the way an operator would — not reachable
through `cargo test`:

1. start `hiaer-spike serve --listen 127.0.0.1:0` (ephemeral port) with
   tight limits and parse the announced address from stdout;
2. run 4 concurrent TCP clients (configure + step_many) — one of them
   disconnects mid-batch without reading its response;
3. run the same schedule over the JSON wire and the negotiated binary
   wire (wire v2 STIM/SPIKES frames) and require identical spike rows,
   then probe with a corrupt binary length prefix and require one
   `malformed_request` line + connection close;
4. check the server still answers `health` (not draining, 0 queue);
5. send SIGTERM and require a clean drain: exit code 0 and the
   "drained" line on stdout.

Stdlib only; every phase is timeout-bounded so a wedged server fails
the run instead of hanging CI. Exit code 0 = pass.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import struct
import subprocess
import sys
import threading
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# binary wire v2 framing (rust/src/sim/frames.rs)
WIRE_SENTINEL = b"\x00"
FRAME_STIM = 0x10
FRAME_SPIKES = 0x90


def pack_stim(rows: list[list[int]]) -> bytes:
    """One complete STIM wire frame for a stimulus batch."""
    parts = [struct.pack("<I", len(rows))]
    for row in rows:
        parts.append(struct.pack("<I", len(row)))
        if row:
            parts.append(struct.pack(f"<{len(row)}I", *row))
    payload = b"".join(parts)
    return WIRE_SENTINEL + struct.pack("<I", len(payload) + 1) + bytes([FRAME_STIM]) + payload


def find_binary(explicit: str | None) -> str:
    if explicit:
        return explicit
    env = os.environ.get("HS_BIN")
    if env:
        return env
    for rel in ("rust/target/release/hiaer-spike", "target/release/hiaer-spike",
                "rust/target/debug/hiaer-spike", "target/debug/hiaer-spike"):
        cand = os.path.join(REPO, rel)
        if os.path.isfile(cand) and os.access(cand, os.X_OK):
            return cand
    sys.exit("serve_smoke: no hiaer-spike binary (build with `cargo build "
             "--release`, or pass --binary / set $HS_BIN)")


class Client:
    """Minimal line-protocol client over one TCP connection."""

    def __init__(self, addr: tuple[str, int], timeout: float):
        self.sock = socket.create_connection(addr, timeout=timeout)
        self.sock.settimeout(timeout)
        self.rfile = self.sock.makefile("r", encoding="utf-8", newline="\n")
        self.wfile = self.sock.makefile("w", encoding="utf-8", newline="\n")
        hello = self.recv()
        assert hello.get("op") == "hello" and hello.get("ok"), f"bad greeting: {hello}"

    def send(self, req: dict) -> None:
        self.wfile.write(json.dumps(req, separators=(",", ":")) + "\n")
        self.wfile.flush()

    def recv(self) -> dict:
        line = self.rfile.readline()
        assert line, "server closed the connection unexpectedly"
        return json.loads(line)

    def request(self, req: dict) -> dict:
        self.send(req)
        resp = self.recv()
        assert resp.get("ok"), f"{req.get('op')} failed: {resp}"
        return resp

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


class BinaryClient:
    """Byte-stream client for the wire-v2 binary path: JSON lines and
    binary frames share one socket, so reads go through a binary file
    object and lines are decoded per-read."""

    def __init__(self, addr: tuple[str, int], timeout: float):
        self.sock = socket.create_connection(addr, timeout=timeout)
        self.sock.settimeout(timeout)
        self.rfile = self.sock.makefile("rb")
        hello = self.recv_json()
        assert hello.get("op") == "hello" and hello.get("ok"), f"bad greeting: {hello}"

    def send_json(self, req: dict) -> None:
        self.sock.sendall((json.dumps(req, separators=(",", ":")) + "\n").encode("utf-8"))

    def recv_json(self) -> dict:
        line = self.rfile.readline()
        assert line, "server closed the connection unexpectedly"
        return json.loads(line)

    def recv_exact(self, n: int) -> bytes:
        data = self.rfile.read(n)
        assert data is not None and len(data) == n, f"short read ({len(data or b'')}/{n})"
        return data

    def recv_spikes(self) -> tuple[list[list[int]], int]:
        first = self.recv_exact(1)
        assert first == WIRE_SENTINEL, f"expected a binary frame, got {first!r}"
        (ln,) = struct.unpack("<I", self.recv_exact(4))
        body = self.recv_exact(ln)
        assert body[0] == FRAME_SPIKES, f"unexpected frame kind 0x{body[0]:02x}"
        payload = body[1:]
        fired_total, n_steps = struct.unpack_from("<QI", payload, 0)
        off = 12
        rows = []
        for _ in range(n_steps):
            (n,) = struct.unpack_from("<I", payload, off)
            off += 4
            rows.append(list(struct.unpack_from(f"<{n}I", payload, off)))
            off += 4 * n
        assert off == len(payload), "trailing bytes in SPIKES payload"
        return rows, fired_total

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def client_task(i: int, addr: tuple[str, int], net: str, timeout: float,
                errors: list[str]) -> None:
    try:
        c = Client(addr, timeout)
        c.request({"op": "configure", "net": net, "seed": 7})
        if i == 0:
            # the rude client: fire a long batch and vanish mid-flight
            c.send({"op": "step_many", "batch": [[0, 1] if s % 3 == 0 else []
                                                 for s in range(200)]})
            c.close()
            return
        resp = c.request({"op": "step_many",
                          "batch": [[0, 1] if s % 2 == 0 else [] for s in range(50)]})
        assert len(resp["spikes"]) == 50, f"client {i}: want 50 rows, got {len(resp['spikes'])}"
        c.request({"op": "shutdown"})
        c.close()
    except Exception as e:  # noqa: BLE001 — collected and failed centrally
        errors.append(f"client {i}: {type(e).__name__}: {e}")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--binary", help="hiaer-spike binary (default: discover)")
    ap.add_argument("--net", default=os.path.join(REPO, "testdata", "fig6_golden.hsn"))
    ap.add_argument("--timeout", type=float, default=60.0,
                    help="hard wall-clock bound for the whole smoke (s)")
    args = ap.parse_args()
    binary = find_binary(args.binary)
    assert os.path.isfile(args.net), f"missing net fixture: {args.net}"

    proc = subprocess.Popen(
        [binary, "serve", "--listen", "127.0.0.1:0",
         "--max-sessions", "8", "--concurrency", "2",
         "--request-timeout-ms", "10000", "--drain-grace-ms", "10000"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
    )
    # hard bound: a wedged server gets killed and the smoke fails
    watchdog = threading.Timer(args.timeout, proc.kill)
    watchdog.daemon = True
    watchdog.start()
    try:
        line = proc.stdout.readline()
        assert line.startswith("listening on "), f"unexpected first line: {line!r}"
        host, _, port = line.strip().rpartition(" ")[2].rpartition(":")
        addr = (host, int(port))
        print(f"serve_smoke: server up at {addr[0]}:{addr[1]}")

        per_client_timeout = max(5.0, args.timeout / 4)
        errors: list[str] = []
        threads = [threading.Thread(target=client_task,
                                    args=(i, addr, args.net, per_client_timeout, errors))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=per_client_timeout)
            assert not t.is_alive(), "client thread wedged"
        assert not errors, "client failures:\n  " + "\n  ".join(errors)
        print("serve_smoke: 4 concurrent clients done (1 disconnected mid-batch)")

        # binary wire (wire v2): the same schedule over both wires must
        # give identical spike rows
        schedule = [[0, 1] if s % 2 == 0 else [] for s in range(64)]
        cj = Client(addr, per_client_timeout)
        cj.request({"op": "configure", "net": args.net, "seed": 7})
        json_rows = cj.request({"op": "step_many", "batch": schedule})["spikes"]
        cj.request({"op": "shutdown"})
        cj.close()

        cb = BinaryClient(addr, per_client_timeout)
        cb.send_json({"op": "configure", "net": args.net, "seed": 7, "wire": "binary"})
        conf = cb.recv_json()
        assert conf.get("ok") and conf.get("wire") == "binary", f"negotiation failed: {conf}"
        cb.sock.sendall(pack_stim(schedule))
        bin_rows, _fired = cb.recv_spikes()
        assert bin_rows == json_rows, (
            f"binary wire diverged from JSON wire: {bin_rows[:3]}... vs {json_rows[:3]}...")
        cb.send_json({"op": "shutdown"})
        cb.recv_json()
        cb.close()
        print(f"serve_smoke: binary wire parity over {len(schedule)} steps")

        # malformed-frame probe: a corrupt length prefix gets one
        # malformed_request line, then the connection closes — and the
        # server keeps serving
        mb = BinaryClient(addr, per_client_timeout)
        mb.send_json({"op": "configure", "net": args.net, "wire": "binary"})
        assert mb.recv_json().get("wire") == "binary"
        mb.sock.sendall(WIRE_SENTINEL + struct.pack("<I", 0xFFFFFFFF))
        resp = mb.recv_json()
        assert resp.get("code") == "malformed_request", f"want malformed_request: {resp}"
        assert mb.rfile.readline() == b"", "connection must close after a corrupt prefix"
        mb.close()
        print("serve_smoke: malformed-frame probe answered and closed")

        # the rude disconnect must not have hurt the server
        c = Client(addr, per_client_timeout)
        health = c.request({"op": "health"})
        assert health.get("draining") is False, f"server draining early: {health}"
        metrics = c.request({"op": "metrics"})
        assert metrics.get("disconnects", 0) >= 1, f"mid-batch disconnect not seen: {metrics}"
        assert metrics.get("steps_total", 0) >= 150, f"too few steps executed: {metrics}"
        c.request({"op": "shutdown"})
        c.close()
        print(f"serve_smoke: healthy after the fault "
              f"(steps_total={metrics.get('steps_total')}, "
              f"disconnects={metrics.get('disconnects')})")

        proc.send_signal(signal.SIGTERM)
        out, err = proc.communicate(timeout=args.timeout)
        assert proc.returncode == 0, (
            f"server exited {proc.returncode} on SIGTERM\nstdout: {out}\nstderr: {err}")
        assert "drained" in out, f"no drain confirmation on stdout: {out!r}"
        print("serve_smoke: SIGTERM -> clean drain, exit 0. PASS")
        return 0
    except AssertionError as e:
        print(f"serve_smoke: FAIL: {e}", file=sys.stderr)
        return 1
    finally:
        watchdog.cancel()
        if proc.poll() is None:
            proc.kill()
            proc.wait()


if __name__ == "__main__":
    sys.exit(main())
